//! Real networked transport: the lockstep leader↔worker protocol over
//! TCP or Unix-domain sockets (DESIGN.md §4).
//!
//! The in-process [`ChannelTransport`](super::ChannelTransport) stays the
//! bitwise oracle; this module moves the *same* protocol across OS
//! processes:
//!
//! * [`TcpTransport`] — the leader side. One socket per worker, a reader
//!   thread per peer forwarding decoded [`Frame`]s onto one event queue,
//!   a writer thread per peer draining a bounded queue, and `Crashed`
//!   tombstone synthesis when a peer's socket dies mid-round — so a
//!   killed worker process surfaces exactly like the fault engine's
//!   scheduled crashes instead of deadlocking the barrier.
//! * [`run_worker`] — the worker process body: connect (with retry /
//!   backoff), handshake (protocol version, worker id, config
//!   fingerprint), then shim frames onto the unchanged
//!   [`worker_loop`] cell.
//! * [`WireCollective`] — the leader's [`Collective`] for lossy codecs
//!   (bf16 wire, QSGD) over the real wire: the payloads are the *actual
//!   socket bytes*, so billed traffic is real traffic by construction.
//! * [`LeaderLink`] — the enum the trainer drives, dispatching to the
//!   in-process channels or the sockets with identical semantics and
//!   error wording.
//!
//! Bitwise equivalence (the tentpole pin): the wire reuses the existing
//! codec bytes verbatim ([`wire::PayloadCodec`]), QSGD draws are keyed by
//! `(seed, stream, use)` ([`wire::qsgd_stream_rng`]) so leader and worker
//! processes derive identical stochastic rounding without shared state,
//! and sync rounds delta-code against mirrored bases that advance in
//! lockstep on both ends. A networked run therefore reproduces the
//! in-process reference bit for bit — model state, loss trace, and the
//! byte accounting, which is pinned `accounted == booked` for every
//! codec. The one intentional difference: the reported `drift_sq`
//! observation is computed from the leader's post-roundtrip state
//! reconstructions (the exact worker states never cross the wire), so it
//! can differ from the in-process value under a *lossy* codec; it only
//! feeds adaptive sync policies, which the networked equivalence matrix
//! runs with fixed H.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::comm::collective::{
    down_stream, grad_stream, mean_sq_dist, up_stream, Collective, CommReport, StreamFamily,
};
use crate::comm::netmodel::NetModel;
use crate::comm::shard::{mean_into_sharded_exec, ShardPlan};
use crate::comm::transport::ChannelTransport;
use crate::comm::wire::{
    self, flags_shard, shard_flags, Frame, FrameBatch, FrameKind, PayloadCodec, CODEC_RAW,
    FLAG_RAW, MAX_BATCH, PROTOCOL_VERSION,
};
use crate::config::ExperimentConfig;
use crate::coordinator::backend::EvalMetrics;
use crate::coordinator::executor::{Executor, Parallelism};
use crate::coordinator::factory::make_factory;
use crate::coordinator::worker::{worker_loop, Cmd, Reply, WorkerSpec};
use crate::error::{Error, Result};
use crate::util::kernels;
use crate::util::pool::{BytePool, PoolStats};

/// Env var for the failure-path tests: a worker process that reads a
/// `SyncStep`/`LocalStep` command for this (1-based) step exits with code
/// 3 *before* replying — a mid-round process death the leader must absorb
/// as a `Crashed` tombstone.
pub const EXIT_AT_STEP_ENV: &str = "ADAALTER_EXIT_AT_STEP";

/// Env var for the graceful-leave tests: a worker process that reads a
/// `SyncStep`/`LocalStep` command for this (1-based) step writes a `Leave`
/// frame and exits cleanly (code 0) *before* executing it — a voluntary
/// departure the leader bills as a leave, not a crash (DESIGN.md §10).
pub const LEAVE_AT_STEP_ENV: &str = "ADAALTER_LEAVE_AT_STEP";

/// Writer-queue depth per peer: deep enough that the strict lockstep
/// protocol (≤ a few in-flight frames per worker) never blocks the
/// leader, bounded so a dead peer cannot buffer unbounded memory.
const WRITER_QUEUE: usize = 64;

// ---------------------------------------------------------------------------
// Byte counters.
// ---------------------------------------------------------------------------

/// Real traffic counters for one networked run, shared by the transport's
/// encode/decode sites and its reader/writer threads.
#[derive(Debug, Default)]
pub struct NetCounters {
    accounted: AtomicU64,
    total: AtomicU64,
}

impl NetCounters {
    /// Fresh zeroed counters.
    pub fn new() -> Arc<NetCounters> {
        Arc::new(NetCounters::default())
    }

    /// Billed codec payload bytes — the frames (and frame sections) that
    /// correspond to the simulated accounting: `SyncStep` model pushes,
    /// `Grad` payloads (minus the piggybacked loss scalar), non-raw
    /// `State` collects and `InstallState` pulls. Pinned equal to the
    /// recorder's booked bytes for every codec.
    pub fn accounted(&self) -> u64 {
        self.accounted.load(Ordering::Relaxed)
    }

    /// Every byte through the leader's sockets, both directions, frame
    /// headers and handshake included — the ground-truth wire volume.
    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    fn add_accounted(&self, b: u64) {
        self.accounted.fetch_add(b, Ordering::Relaxed);
    }

    fn add_total(&self, b: u64) {
        self.total.fetch_add(b, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Socket plumbing: TCP / Unix-domain behind one face.
// ---------------------------------------------------------------------------

/// Which socket family the `[comm]` section selected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SocketKind {
    /// `comm.transport = "tcp"` — TCP over loopback or a real network.
    Tcp,
    /// `comm.transport = "uds"` — Unix-domain sockets (same frames).
    Uds,
}

impl SocketKind {
    /// Map a `comm.transport` spelling to a socket family.
    pub fn from_transport(t: &str) -> Option<SocketKind> {
        match t {
            "tcp" => Some(SocketKind::Tcp),
            "uds" => Some(SocketKind::Uds),
            _ => None,
        }
    }
}

/// One connected peer stream (TCP or Unix-domain), `Read + Write`.
enum NetStream {
    Tcp(TcpStream),
    Uds(UnixStream),
}

impl NetStream {
    fn connect(kind: SocketKind, addr: &str) -> std::io::Result<NetStream> {
        match kind {
            SocketKind::Tcp => TcpStream::connect(addr).map(NetStream::Tcp),
            SocketKind::Uds => UnixStream::connect(addr).map(NetStream::Uds),
        }
    }

    fn try_clone(&self) -> std::io::Result<NetStream> {
        match self {
            NetStream::Tcp(s) => s.try_clone().map(NetStream::Tcp),
            NetStream::Uds(s) => s.try_clone().map(NetStream::Uds),
        }
    }

    fn set_nodelay(&self, on: bool) {
        if let NetStream::Tcp(s) = self {
            let _ = s.set_nodelay(on);
        }
    }

    fn set_read_timeout(&self, t: Option<Duration>) {
        let _ = match self {
            NetStream::Tcp(s) => s.set_read_timeout(t),
            NetStream::Uds(s) => s.set_read_timeout(t),
        };
    }
}

impl Read for NetStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.read(buf),
            NetStream::Uds(s) => s.read(buf),
        }
    }
}

impl Write for NetStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.write(buf),
            NetStream::Uds(s) => s.write(buf),
        }
    }

    // Delegate so a coalesced FrameBatch submission reaches the kernel as
    // one writev(2) instead of the Write default's first-buffer-only
    // fallback (which would degrade the pipelined path to a syscall per
    // frame section).
    fn write_vectored(&mut self, bufs: &[std::io::IoSlice<'_>]) -> std::io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.write_vectored(bufs),
            NetStream::Uds(s) => s.write_vectored(bufs),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            NetStream::Tcp(s) => s.flush(),
            NetStream::Uds(s) => s.flush(),
        }
    }
}

enum NetListener {
    Tcp(TcpListener),
    Uds(UnixListener),
}

impl NetListener {
    fn bind(kind: SocketKind, addr: &str) -> Result<(NetListener, String)> {
        match kind {
            SocketKind::Tcp => {
                let l = TcpListener::bind(addr).map_err(|e| {
                    Error::Config(format!("net.listen: cannot bind {addr:?}: {e}"))
                })?;
                let local = l
                    .local_addr()
                    .map(|a| a.to_string())
                    .unwrap_or_else(|_| addr.to_string());
                Ok((NetListener::Tcp(l), local))
            }
            SocketKind::Uds => {
                // A stale socket file from a previous run blocks the bind.
                let _ = std::fs::remove_file(addr);
                let l = UnixListener::bind(addr).map_err(|e| {
                    Error::Config(format!("net.listen: cannot bind {addr:?}: {e}"))
                })?;
                Ok((NetListener::Uds(l), addr.to_string()))
            }
        }
    }

    fn set_nonblocking(&self, on: bool) -> std::io::Result<()> {
        match self {
            NetListener::Tcp(l) => l.set_nonblocking(on),
            NetListener::Uds(l) => l.set_nonblocking(on),
        }
    }

    fn accept(&self) -> std::io::Result<NetStream> {
        match self {
            NetListener::Tcp(l) => l.accept().map(|(s, _)| NetStream::Tcp(s)),
            NetListener::Uds(l) => l.accept().map(|(s, _)| NetStream::Uds(s)),
        }
    }
}

/// Atomically publish the leader's bound address for workers started with
/// `--port-file` (write to a temp file, then rename — a reader never sees
/// a partial address).
pub fn write_port_file(path: &str, addr: &str) -> Result<()> {
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, format!("{addr}\n"))?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Poll `path` until it holds a non-empty address line (the leader binds
/// port 0 and publishes the chosen port here), up to `timeout`.
pub fn read_port_file(path: &str, timeout: Duration) -> Result<String> {
    let start = Instant::now();
    loop {
        if let Ok(s) = std::fs::read_to_string(path) {
            let line = s.trim();
            if !line.is_empty() {
                return Ok(line.to_string());
            }
        }
        if start.elapsed() > timeout {
            return Err(Error::Config(format!(
                "net.connect: port file {path:?} never appeared within \
                 net.connect_timeout_s = {}s — the leader likely died before \
                 publishing its address",
                timeout.as_secs_f64()
            )));
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

// ---------------------------------------------------------------------------
// Shared wire state: codec + delta bases + pending round data.
// ---------------------------------------------------------------------------

/// The leader's codec-side state, shared between the [`TcpTransport`]
/// (which encodes commands / decodes replies) and the [`WireCollective`]
/// (which averages the decoded deltas and stages the down-leg payload).
/// Both run on the leader thread; the mutex is uncontended.
pub struct WireState {
    codec: PayloadCodec,
    n: usize,
    d: usize,
    /// Leader-shard range partition (`comm.shards`; dense when k = 1).
    /// Sync-round `State`/`InstallState` payloads are split into one
    /// shard-tagged frame per range — the addressing a k-shard-server
    /// deployment uses — and reassembled in arrival (FIFO) order.
    plan: ShardPlan,
    /// Last synchronized parameters (delta base; zeros before round 1) —
    /// mirrored exactly by every worker process.
    base_x: Vec<f32>,
    /// Last synchronized denominators (same mirroring).
    base_acc: Vec<f32>,
    /// Decoded (post-roundtrip) up-leg parameter deltas of the round in
    /// flight, per worker.
    pending_x: Vec<Option<Vec<f32>>>,
    /// Decoded up-leg accumulator deltas.
    pending_acc: Vec<Option<Vec<f32>>>,
    /// Encoded down-leg payload staged by the last sync round, consumed by
    /// the next `remaining` `InstallState` frames.
    install: Option<InstallStash>,
}

struct InstallStash {
    payload: Vec<u8>,
    remaining: usize,
}

impl WireState {
    /// Fresh state for an `n`-worker, dimension-`d` cluster using `codec`
    /// for data payloads (single leader shard).
    pub fn new(codec: PayloadCodec, n: usize, d: usize) -> Arc<Mutex<WireState>> {
        WireState::sharded(codec, n, d, 1)
    }

    /// Fresh state with `shards` leader shards (`comm.shards`): sync-round
    /// data frames are split/reassembled per [`ShardPlan`] range. `k = 1`
    /// is byte-identical to the pre-sharding wire.
    pub fn sharded(
        codec: PayloadCodec,
        n: usize,
        d: usize,
        shards: usize,
    ) -> Arc<Mutex<WireState>> {
        Arc::new(Mutex::new(WireState {
            codec,
            n,
            d,
            plan: ShardPlan::new(d, shards),
            base_x: vec![0.0; d],
            base_acc: vec![0.0; d],
            pending_x: vec![None; n],
            pending_acc: vec![None; n],
            install: None,
        }))
    }

    /// The data-payload codec the `[comm]`/`[precision]` sections select —
    /// the same choice on the leader and in every worker process.
    pub fn codec_for(cfg: &ExperimentConfig) -> PayloadCodec {
        if cfg.comm.compression == "qsgd" {
            PayloadCodec::qsgd(cfg.comm.qsgd_levels, cfg.train.seed)
        } else if cfg.precision.wire_bf16() {
            PayloadCodec::Bf16
        } else {
            PayloadCodec::F32
        }
    }
}

fn lock(state: &Arc<Mutex<WireState>>) -> std::sync::MutexGuard<'_, WireState> {
    state.lock().expect("wire state lock poisoned")
}

// ---------------------------------------------------------------------------
// Payload helpers.
// ---------------------------------------------------------------------------

fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    out.reserve(4 * v.len());
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn get_f32s(bytes: &[u8], d: usize) -> Result<Vec<f32>> {
    if bytes.len() != 4 * d {
        return Err(Error::Protocol(format!(
            "raw f32 payload length {} != {} for a {d}-element vector",
            bytes.len(),
            4 * d
        )));
    }
    Ok((0..d)
        .map(|i| f32::from_le_bytes(bytes[4 * i..4 * i + 4].try_into().expect("sized")))
        .collect())
}

/// Split a raw-f32 state payload into `x` and an optional `acc` section —
/// the payload is `4d` (x only) or `8d` (x then acc) bytes.
fn split_raw_state(bytes: &[u8], d: usize) -> Result<(Vec<f32>, Option<Vec<f32>>)> {
    if bytes.len() == 4 * d {
        Ok((get_f32s(bytes, d)?, None))
    } else if bytes.len() == 8 * d {
        Ok((get_f32s(&bytes[..4 * d], d)?, Some(get_f32s(&bytes[4 * d..], d)?)))
    } else {
        Err(Error::Protocol(format!(
            "raw state payload length {} is neither {} nor {} (d = {d})",
            bytes.len(),
            4 * d,
            8 * d
        )))
    }
}

/// Split an encoded state payload into its per-family sections — one or
/// two sections of exactly `enc_len` bytes each (x, then acc).
fn split_enc_state(bytes: &[u8], enc_len: usize) -> Result<(&[u8], Option<&[u8]>)> {
    if bytes.len() == enc_len {
        Ok((bytes, None))
    } else if bytes.len() == 2 * enc_len {
        Ok((&bytes[..enc_len], Some(&bytes[enc_len..])))
    } else {
        Err(Error::Protocol(format!(
            "encoded state payload length {} is neither {enc_len} nor {}",
            bytes.len(),
            2 * enc_len
        )))
    }
}

// ---------------------------------------------------------------------------
// Shard-addressed framing (comm.shards > 1; DESIGN.md §3).
// ---------------------------------------------------------------------------

/// Split a dense sync-round state payload into one payload per leader
/// shard. The dense payload is 1 or 2 equal elementwise-encoded sections
/// (x, then acc) of `elem`·d bytes each; shard `s` carries the byte
/// range of its index range from every section, sections concatenated in
/// order. Purely a byte repartition: reassembling the shard payloads
/// reproduces the dense bytes exactly, so decoded values and billing
/// sums are bit-identical to the unsharded wire.
fn split_state_payload(payload: &[u8], elem: usize, plan: &ShardPlan) -> Result<Vec<Vec<u8>>> {
    let d = plan.dim();
    let sec = elem * d;
    let sections = if sec == 0 { 0 } else { payload.len() / sec };
    if sec == 0 || payload.len() != sections * sec || !(1..=2).contains(&sections) {
        return Err(Error::Protocol(format!(
            "state payload length {} is not 1–2 sections of {sec} bytes (d = {d})",
            payload.len()
        )));
    }
    Ok(plan
        .ranges()
        .map(|r| {
            let mut p = Vec::with_capacity(sections * elem * r.len());
            for s in 0..sections {
                p.extend_from_slice(&payload[s * sec + elem * r.start..s * sec + elem * r.end]);
            }
            p
        })
        .collect())
}

/// In-order reassembly of shard-tagged state frames back into the dense
/// payload. Each shard frame interleaves its x and acc slices, so the
/// sections are accumulated separately and concatenated at the end. TCP
/// (and the Unix-domain stream) delivers per-connection FIFO, so shards
/// arrive in index order; anything else is a protocol error. Reusable:
/// completing an assembly resets it for the next round.
#[derive(Default)]
struct ShardAssembly {
    next: usize,
    sections: Vec<Vec<u8>>,
}

impl ShardAssembly {
    /// Fold in shard `shard`'s payload. Returns the assembled dense
    /// payload once the last shard arrived, `None` while partial.
    fn push(
        &mut self,
        plan: &ShardPlan,
        elem: usize,
        shard: usize,
        payload: &[u8],
    ) -> Result<Option<Vec<u8>>> {
        if shard != self.next || shard >= plan.shards() {
            return Err(Error::Protocol(format!(
                "shard frame {shard} arrived out of order (expected shard {} of {})",
                self.next,
                plan.shards()
            )));
        }
        let r = plan.range(shard);
        if self.sections.is_empty() {
            // Section count is inferred from shard 0, which is never
            // empty (the plan front-loads the remainder).
            let sec = elem * r.len();
            let sections = if sec == 0 { 0 } else { payload.len() / sec };
            if sec == 0 || payload.len() != sections * sec || !(1..=2).contains(&sections) {
                return Err(Error::Protocol(format!(
                    "shard 0 payload length {} is not 1–2 sections of {sec} bytes",
                    payload.len()
                )));
            }
            self.sections = vec![Vec::new(); sections];
        }
        let sec = elem * r.len();
        if payload.len() != self.sections.len() * sec {
            return Err(Error::Protocol(format!(
                "shard {shard} payload length {} != {} sections × {sec} bytes",
                payload.len(),
                self.sections.len()
            )));
        }
        for (i, out) in self.sections.iter_mut().enumerate() {
            out.extend_from_slice(&payload[i * sec..(i + 1) * sec]);
        }
        self.next += 1;
        if self.next < plan.shards() {
            return Ok(None);
        }
        self.next = 0;
        Ok(Some(std::mem::take(&mut self.sections).concat()))
    }
}

// ---------------------------------------------------------------------------
// Handshake.
// ---------------------------------------------------------------------------

/// `HelloAck` payload: cluster shape + the per-worker spec fields the
/// worker process cannot derive from its own config, + the shared init.
fn encode_hello_ack(n: usize, spec: &WorkerSpec) -> Vec<u8> {
    let mut p = Vec::with_capacity(16 + 4 * spec.init.len());
    p.extend_from_slice(&(n as u32).to_le_bytes());
    p.push(spec.allow_fused as u8);
    p.push(spec.collect_update_sq as u8);
    p.push(spec.bf16_state as u8);
    p.push(0);
    p.extend_from_slice(&spec.crash_step.map_or(0u64, |s| s + 1).to_le_bytes());
    put_f32s(&mut p, &spec.init);
    p
}

/// The decoded `HelloAck` a worker process builds its cell spec from.
struct HelloAck {
    n: usize,
    allow_fused: bool,
    collect_update_sq: bool,
    bf16_state: bool,
    crash_step: Option<u64>,
    init: Vec<f32>,
}

fn decode_hello_ack(p: &[u8]) -> Result<HelloAck> {
    if p.len() < 16 || (p.len() - 16) % 4 != 0 {
        return Err(Error::Protocol(format!("malformed HelloAck payload ({} bytes)", p.len())));
    }
    let n = u32::from_le_bytes(p[0..4].try_into().expect("sized")) as usize;
    let crash = u64::from_le_bytes(p[8..16].try_into().expect("sized"));
    let d = (p.len() - 16) / 4;
    Ok(HelloAck {
        n,
        allow_fused: p[4] != 0,
        collect_update_sq: p[5] != 0,
        bf16_state: p[6] != 0,
        crash_step: crash.checked_sub(1),
        init: get_f32s(&p[16..], d)?,
    })
}

// ---------------------------------------------------------------------------
// TcpTransport — the leader side.
// ---------------------------------------------------------------------------

/// A bound-but-not-yet-connected leader endpoint: lets the caller publish
/// the chosen address (port-0 binds) *before* blocking in the handshake.
pub struct Bound {
    listener: NetListener,
    addr: String,
    timeout: Duration,
}

impl Bound {
    /// The actual bound address ("127.0.0.1:41234" for TCP port-0 binds;
    /// the socket path for Unix-domain).
    pub fn local_addr(&self) -> &str {
        &self.addr
    }

    /// Accept and handshake all `specs.len()` workers: each must present
    /// the protocol version, a fresh in-range worker id and the matching
    /// config fingerprint; violators get an `ErrMsg` frame and are
    /// dropped while the leader keeps listening. Returns the running
    /// transport (reader/writer threads spawned per peer). The listener
    /// stays open on an accept thread for the lifetime of the transport:
    /// late `Join` handshakes from relaunched worker processes are parked
    /// until the leader admits them at a sync-round boundary
    /// ([`TcpTransport::admit_join`], DESIGN.md §10).
    pub fn handshake(
        self,
        specs: &[WorkerSpec],
        fingerprint: u64,
        nodelay: bool,
        state: Arc<Mutex<WireState>>,
        counters: Arc<NetCounters>,
        pipeline: usize,
    ) -> Result<TcpTransport> {
        let n = specs.len();
        let deadline = Instant::now() + self.timeout;
        self.listener.set_nonblocking(true)?;
        let mut conns: Vec<Option<NetStream>> = (0..n).map(|_| None).collect();
        let mut connected = 0usize;
        while connected < n {
            if Instant::now() > deadline {
                return Err(Error::Config(format!(
                    "net.listen: {} of {n} workers never connected within \
                     net.connect_timeout_s = {}s",
                    n - connected,
                    self.timeout.as_secs_f64()
                )));
            }
            let mut stream = match self.listener.accept() {
                Ok(s) => s,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                    continue;
                }
                Err(e) => return Err(e.into()),
            };
            stream.set_read_timeout(Some(Duration::from_secs(5)));
            let hello = match Frame::read_from(&mut stream) {
                Ok(Some(f)) if f.kind == FrameKind::Hello && f.payload.len() == 8 => f,
                // Not a valid hello (wrong version / kind / garbage):
                // drop the connection and keep listening.
                _ => continue,
            };
            counters.add_total(hello.wire_len() as u64);
            let w = hello.worker as usize;
            let peer_fp = u64::from_le_bytes(hello.payload[..8].try_into().expect("sized"));
            let reject = if w >= n {
                Some(format!("worker id {w} out of range (cluster size {n})"))
            } else if conns[w].is_some() {
                Some(format!("duplicate worker id {w}"))
            } else if peer_fp != fingerprint {
                Some(format!(
                    "config mismatch: worker fingerprint {peer_fp:#018x} != leader \
                     {fingerprint:#018x} — leader and workers must run the identical \
                     experiment config"
                ))
            } else {
                None
            };
            if let Some(msg) = reject {
                let f = Frame {
                    kind: FrameKind::ErrMsg,
                    codec: CODEC_RAW,
                    flags: 0,
                    worker: hello.worker,
                    step: 0,
                    payload: msg.into_bytes(),
                };
                counters.add_total(f.wire_len() as u64);
                let _ = f.write_to(&mut stream);
                continue;
            }
            let ack = Frame {
                kind: FrameKind::HelloAck,
                codec: CODEC_RAW,
                flags: 0,
                worker: hello.worker,
                step: 0,
                payload: encode_hello_ack(n, &specs[w]),
            };
            counters.add_total(ack.wire_len() as u64);
            ack.write_to(&mut stream)?;
            stream.set_read_timeout(None);
            stream.set_nodelay(nodelay);
            conns[w] = Some(stream);
            connected += 1;
        }
        // Rejoin acks are pre-encoded with the crash schedule stripped:
        // a relaunched worker must not replay the death that took it out.
        let ack_payloads = specs
            .iter()
            .map(|s| {
                let mut p = encode_hello_ack(n, s);
                p[8..16].copy_from_slice(&0u64.to_le_bytes());
                p
            })
            .collect();
        TcpTransport::start(
            conns.into_iter().map(|c| c.expect("all connected")).collect(),
            state,
            counters,
            JoinSource { listener: self.listener, fingerprint, nodelay },
            ack_payloads,
            pipeline,
        )
    }
}

/// What the accept thread needs to validate and park late `Join`
/// handshakes: the still-open listener plus the initial handshake's
/// fingerprint and socket options.
struct JoinSource {
    listener: NetListener,
    fingerprint: u64,
    nodelay: bool,
}

struct Peer {
    tx: Option<SyncSender<Frame>>,
    writer: Option<JoinHandle<()>>,
    reader: Option<JoinHandle<()>>,
}

/// The leader side of the networked transport: the exact request/reply
/// surface of [`ChannelTransport`] (`broadcast`/`broadcast_to`/`gather`/
/// `gather_from`/`shutdown`, same error wording) over one socket per
/// worker, with per-peer reader/writer threads and bounded write queues.
///
/// Peer death (EOF or socket error on the reader) synthesizes the same
/// [`Reply::Crashed`] tombstone the in-process fault engine produces: one
/// tombstone for the command in flight, and one per subsequent command
/// addressed to the dead worker — so quorum policies keep the run alive
/// and full-barrier runs fail with a clean protocol error, never a hang.
pub struct TcpTransport {
    peers: Vec<Peer>,
    events: Receiver<(usize, u64, Option<Frame>)>,
    /// Kept open so [`TcpTransport::admit_join`] can spawn reader threads
    /// for re-admitted peers; consequently the event channel never closes
    /// on its own and `recv` detects the all-dead state explicitly.
    ev_tx: Sender<(usize, u64, Option<Frame>)>,
    state: Arc<Mutex<WireState>>,
    counters: Arc<NetCounters>,
    /// Synthesized tombstones queued ahead of socket events.
    synth: VecDeque<Reply>,
    dead: Vec<bool>,
    /// Peers whose last word was a voluntary `Leave` frame — their
    /// subsequent EOF is expected, not a crash.
    left: Vec<bool>,
    /// Step of the last frame received from each peer (postmortem
    /// context for the all-workers-disconnected error).
    last_step: Vec<u64>,
    /// Per-peer connection epoch: reader threads stamp their events with
    /// the generation they were spawned under, so a stale EOF from a
    /// replaced connection cannot kill a re-admitted peer.
    gen: Vec<u64>,
    /// Commands in flight per worker (≤ 1 in the lockstep protocol).
    outstanding: Vec<usize>,
    /// Per-worker reassembly of shard-tagged `State` frames
    /// (`comm.shards > 1`; idle on the dense plan).
    assembly: Vec<ShardAssembly>,
    /// Pre-encoded rejoin `HelloAck` payloads (crash schedule stripped).
    ack_payloads: Vec<Vec<u8>>,
    /// Validated late handshakes parked by the accept thread, awaiting
    /// boundary admission.
    pending: Arc<Mutex<Vec<(usize, NetStream)>>>,
    accept_stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    /// `comm.pipeline` depth for the writer threads (< 2 = serial path).
    pipeline: usize,
    /// Shared wire-payload staging pool: `cmd_to_frame` takes buffers
    /// here, coalescing writers recycle them after submission — the
    /// encode → frame → queue cycle is allocation-free at steady state.
    pool: Arc<Mutex<BytePool>>,
}

/// Spawn the reader/writer thread pair for one connected peer. The
/// reader stamps every event with `generation` so replaced connections
/// can be told apart from live ones.
///
/// `pipeline < 2` keeps the writer on the strictly-serial path (one
/// encode, one write, one flush per frame — today's behavior by
/// construction). `pipeline ≥ 2` turns on frame coalescing: the writer
/// drains up to `pipeline` already-queued frames per wake-up, stages
/// their headers in one reusable buffer, and submits all
/// `[header][payload]` pairs with a single vectored write + flush,
/// recycling payload buffers into `pool` afterwards. Scheduling only:
/// the per-peer frame order is FIFO either way.
fn spawn_peer(
    w: usize,
    generation: u64,
    stream: NetStream,
    ev_tx: &Sender<(usize, u64, Option<Frame>)>,
    counters: &Arc<NetCounters>,
    pipeline: usize,
    pool: &Arc<Mutex<BytePool>>,
) -> Result<Peer> {
    let mut rd = stream.try_clone()?;
    let mut wr = stream;
    let (tx, rx) = std::sync::mpsc::sync_channel::<Frame>(WRITER_QUEUE);
    let rc = Arc::clone(counters);
    let etx = ev_tx.clone();
    let reader = std::thread::spawn(move || loop {
        match Frame::read_from(&mut rd) {
            Ok(Some(f)) => {
                rc.add_total(f.wire_len() as u64);
                if etx.send((w, generation, Some(f))).is_err() {
                    break;
                }
            }
            // Clean EOF and read errors alike mean the peer is gone
            // mid-protocol; the leader turns this into a Crashed
            // tombstone (or absorbs it silently after a Leave).
            Ok(None) | Err(_) => {
                let _ = etx.send((w, generation, None));
                break;
            }
        }
    });
    let wc = Arc::clone(counters);
    let wp = Arc::clone(pool);
    let depth = pipeline.clamp(1, MAX_BATCH);
    let writer = std::thread::spawn(move || {
        if depth < 2 {
            while let Ok(f) = rx.recv() {
                if f.write_to(&mut wr).is_err() {
                    break;
                }
                wc.add_total(f.wire_len() as u64);
                let _ = wr.flush();
            }
            return;
        }
        let mut batch = FrameBatch::new();
        // `recv` keeps yielding frames buffered before the sender closed,
        // and each iteration writes + flushes everything it staged before
        // blocking again — so channel close (shutdown, Leave) can never
        // strand a staged partial batch.
        while let Ok(first) = rx.recv() {
            batch.stage(first);
            while batch.len() < depth {
                match rx.try_recv() {
                    Ok(f) => batch.stage(f),
                    Err(_) => break,
                }
            }
            let bytes = batch.wire_len();
            let ok = batch.write_to(&mut wr).is_ok();
            // Recycle payload allocations for the next round's encodes.
            // The pool is an optimization, never a correctness dependency:
            // under lock contention the buffers are simply dropped.
            match wp.try_lock() {
                Ok(mut p) => batch.recycle_into(&mut p),
                Err(_) => batch.clear(),
            }
            if !ok {
                break;
            }
            wc.add_total(bytes);
            let _ = wr.flush();
        }
        let _ = wr.flush();
    });
    Ok(Peer { tx: Some(tx), writer: Some(writer), reader: Some(reader) })
}

impl TcpTransport {
    /// Bind the leader's listening socket. `timeout` bounds both the
    /// handshake accept loop and is reused by workers polling the port
    /// file.
    pub fn listen(kind: SocketKind, addr: &str, timeout: Duration) -> Result<Bound> {
        if addr.is_empty() {
            return Err(Error::Config(
                "net.listen: no listen address (set [net] listen or --listen)".into(),
            ));
        }
        let (listener, local) = NetListener::bind(kind, addr)?;
        Ok(Bound { listener, addr: local, timeout })
    }

    fn start(
        streams: Vec<NetStream>,
        state: Arc<Mutex<WireState>>,
        counters: Arc<NetCounters>,
        join: JoinSource,
        ack_payloads: Vec<Vec<u8>>,
        pipeline: usize,
    ) -> Result<TcpTransport> {
        let n = streams.len();
        let (ev_tx, ev_rx) = std::sync::mpsc::channel::<(usize, u64, Option<Frame>)>();
        let pool = Arc::new(Mutex::new(BytePool::new()));
        let mut peers = Vec::with_capacity(n);
        for (w, stream) in streams.into_iter().enumerate() {
            peers.push(spawn_peer(w, 0, stream, &ev_tx, &counters, pipeline, &pool)?);
        }
        // The accept thread: poll the still-open listener, validate late
        // `Join` handshakes (kind, id range, fingerprint — same rules as
        // the initial hello) and park them for boundary admission. The
        // `HelloAck` is deliberately NOT sent here: admission is the
        // leader's decision, and the ack is the admission signal the
        // rejoining worker blocks on.
        let pending: Arc<Mutex<Vec<(usize, NetStream)>>> = Arc::new(Mutex::new(Vec::new()));
        let accept_stop = Arc::new(AtomicBool::new(false));
        let accept_thread = {
            let pending = Arc::clone(&pending);
            let stop = Arc::clone(&accept_stop);
            let counters = Arc::clone(&counters);
            let JoinSource { listener, fingerprint, nodelay } = join;
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    let mut stream = match listener.accept() {
                        Ok(s) => s,
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(10));
                            continue;
                        }
                        Err(_) => break,
                    };
                    stream.set_read_timeout(Some(Duration::from_secs(5)));
                    let join = match Frame::read_from(&mut stream) {
                        Ok(Some(f)) if f.kind == FrameKind::Join && f.payload.len() == 8 => f,
                        // Not a valid late handshake: drop and keep
                        // listening.
                        _ => continue,
                    };
                    counters.add_total(join.wire_len() as u64);
                    let w = join.worker as usize;
                    let peer_fp =
                        u64::from_le_bytes(join.payload[..8].try_into().expect("sized"));
                    let reject = if w >= n {
                        Some(format!("worker id {w} out of range (cluster size {n})"))
                    } else if peer_fp != fingerprint {
                        Some(format!(
                            "config mismatch: worker fingerprint {peer_fp:#018x} != leader \
                             {fingerprint:#018x} — leader and workers must run the identical \
                             experiment config"
                        ))
                    } else {
                        None
                    };
                    if let Some(msg) = reject {
                        let f = Frame {
                            kind: FrameKind::ErrMsg,
                            codec: CODEC_RAW,
                            flags: 0,
                            worker: join.worker,
                            step: 0,
                            payload: msg.into_bytes(),
                        };
                        counters.add_total(f.wire_len() as u64);
                        let _ = f.write_to(&mut stream);
                        continue;
                    }
                    stream.set_nodelay(nodelay);
                    if let Ok(mut p) = pending.lock() {
                        p.push((w, stream));
                    }
                }
            })
        };
        Ok(TcpTransport {
            peers,
            events: ev_rx,
            ev_tx,
            state,
            counters,
            synth: VecDeque::new(),
            dead: vec![false; n],
            left: vec![false; n],
            last_step: vec![0; n],
            gen: vec![0; n],
            outstanding: vec![0; n],
            assembly: (0..n).map(|_| ShardAssembly::default()).collect(),
            ack_payloads,
            pending,
            accept_stop,
            accept_thread: Some(accept_thread),
            pipeline,
            pool,
        })
    }

    /// Number of workers.
    pub fn n(&self) -> usize {
        self.peers.len()
    }

    /// The shared traffic counters (for end-of-run reporting).
    pub fn counters(&self) -> Arc<NetCounters> {
        Arc::clone(&self.counters)
    }

    /// Is peer `w`'s socket dead (crashed or departed)? Out-of-range ids
    /// read as dead.
    pub fn peer_dead(&self, w: usize) -> bool {
        self.dead.get(w).copied().unwrap_or(true)
    }

    /// Worker ids with a validated late handshake parked and awaiting
    /// admission (sorted, deduplicated). Non-blocking — the accept thread
    /// fills the queue; the leader polls it at sync-round boundaries.
    pub fn poll_joins(&self) -> Vec<usize> {
        let p = self.pending.lock().expect("pending-join lock poisoned");
        let mut ids: Vec<usize> = p.iter().map(|&(w, _)| w).collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Admit worker `w`'s parked late handshake: send the rejoin
    /// `HelloAck` (crash schedule stripped), replace the dead peer's
    /// reader/writer threads with a pair on the new connection, and reset
    /// the peer's protocol state. The caller (the leader, at a sync-round
    /// boundary) then warm-starts the worker via the normal
    /// `InstallState` catch-up path. If the worker reconnected more than
    /// once, the newest connection wins and stale ones are dropped.
    pub fn admit_join(&mut self, w: usize) -> Result<()> {
        if w >= self.n() {
            return Err(Error::Protocol(format!("no worker {w}")));
        }
        let mut stream = {
            let mut p = self.pending.lock().expect("pending-join lock poisoned");
            let mut found = None;
            let mut i = 0;
            while i < p.len() {
                if p[i].0 == w {
                    found = Some(p.remove(i).1);
                } else {
                    i += 1;
                }
            }
            found.ok_or_else(|| {
                Error::Protocol(format!("no pending join from worker {w} to admit"))
            })?
        };
        let ack = Frame {
            kind: FrameKind::HelloAck,
            codec: CODEC_RAW,
            flags: 0,
            worker: w as u32,
            step: 0,
            payload: self.ack_payloads[w].clone(),
        };
        self.counters.add_total(ack.wire_len() as u64);
        ack.write_to(&mut stream)?;
        stream.set_read_timeout(None);
        // New connection epoch: events from the replaced connection's
        // reader (e.g. its trailing EOF) are ignored from here on.
        self.gen[w] += 1;
        let peer = spawn_peer(
            w,
            self.gen[w],
            stream,
            &self.ev_tx,
            &self.counters,
            self.pipeline,
            &self.pool,
        )?;
        let mut old = std::mem::replace(&mut self.peers[w], peer);
        old.tx = None;
        if let Some(j) = old.writer.take() {
            let _ = j.join();
        }
        if let Some(j) = old.reader.take() {
            let _ = j.join();
        }
        self.dead[w] = false;
        self.left[w] = false;
        self.outstanding[w] = 0;
        self.assembly[w] = ShardAssembly::default();
        Ok(())
    }

    /// Send `make(w)` to every worker.
    pub fn broadcast(&mut self, mut make: impl FnMut(usize) -> Cmd) -> Result<()> {
        for w in 0..self.n() {
            self.send_to(w, make(w))?;
        }
        Ok(())
    }

    /// Send `make(w)` to each worker in `targets`.
    pub fn broadcast_to(
        &mut self,
        targets: &[usize],
        mut make: impl FnMut(usize) -> Cmd,
    ) -> Result<()> {
        for &w in targets {
            self.send_to(w, make(w))?;
        }
        Ok(())
    }

    /// Send one command to worker `w`. Addressing a dead peer synthesizes
    /// an immediate `Crashed` tombstone instead of erroring — the same
    /// contract as the in-process fault engine's dead cells.
    pub fn send_to(&mut self, w: usize, cmd: Cmd) -> Result<()> {
        if w >= self.n() {
            return Err(Error::Protocol(format!("no worker {w}")));
        }
        if self.dead[w] {
            self.synth.push_back(Reply::Crashed { worker: w, step: 0 });
            return Ok(());
        }
        let frame = self.cmd_to_frame(w, cmd)?;
        let frames = self.shard_install_frames(frame)?;
        self.outstanding[w] += 1;
        let sent = match self.peers[w].tx.as_ref() {
            Some(tx) => frames.into_iter().all(|f| tx.send(f).is_ok()),
            None => false,
        };
        if !sent {
            self.dead[w] = true;
            self.outstanding[w] = 0;
            self.synth.push_back(Reply::Crashed { worker: w, step: 0 });
        }
        Ok(())
    }

    /// Receive the next reply from any worker (or a synthesized
    /// tombstone).
    pub fn recv(&mut self) -> Result<Reply> {
        if let Some(r) = self.synth.pop_front() {
            return Ok(r);
        }
        loop {
            // The event channel stays open for the transport's lifetime
            // (`ev_tx` is held for join admissions), so the all-dead
            // terminal state is detected explicitly instead of via
            // channel closure.
            if self.dead.iter().all(|&d| d) {
                return Err(self.all_disconnected());
            }
            match self.events.recv() {
                Ok((w, g, _)) if g != self.gen[w] => {
                    // Stale event from a connection that was since
                    // replaced by a rejoin admission.
                }
                Ok((w, _, Some(frame))) => {
                    self.last_step[w] = frame.step;
                    if let Some(reply) = self.frame_to_reply(w, frame)? {
                        self.outstanding[w] = self.outstanding[w].saturating_sub(1);
                        return Ok(reply);
                    }
                    // Partial shard frame of a sync collect in flight —
                    // keep reading until its last shard lands.
                }
                Ok((w, _, None)) => {
                    if !self.dead[w] {
                        self.dead[w] = true;
                        // A voluntary Leave already answered the command
                        // in flight; the trailing EOF is expected and
                        // must not be billed as a crash.
                        if !self.left[w] && self.outstanding[w] > 0 {
                            self.outstanding[w] = 0;
                            return Ok(Reply::Crashed { worker: w, step: 0 });
                        }
                        self.outstanding[w] = 0;
                    }
                    // No command in flight: remember the death, keep
                    // waiting for the workers that are.
                }
                Err(_) => return Err(self.all_disconnected()),
            }
        }
    }

    /// The terminal no-peers-left error, with the per-peer postmortem the
    /// ISSUE asks for: each worker's last-known protocol state and the
    /// step of its last frame — so a real-cluster failure report starts
    /// from the membership picture, not a bare string.
    fn all_disconnected(&self) -> Error {
        let states: Vec<String> = (0..self.n())
            .map(|w| {
                let state = if self.left[w] {
                    "left"
                } else if self.dead[w] {
                    "crashed"
                } else {
                    "active"
                };
                format!("w{w}: {state}, last frame at step {}", self.last_step[w])
            })
            .collect();
        Error::Protocol(format!(
            "all workers disconnected (last-known peer states: {})",
            states.join("; ")
        ))
    }

    /// Best-effort shutdown: `stop(w)` to every live peer, then join the
    /// per-peer threads (workers close their sockets on `Stop`, which
    /// unblocks the readers).
    pub fn shutdown(&mut self, mut stop: impl FnMut(usize) -> Cmd) {
        self.accept_stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.accept_thread.take() {
            let _ = j.join();
        }
        for w in 0..self.peers.len() {
            if !self.dead[w] {
                if let Ok(frame) = self.cmd_to_frame(w, stop(w)) {
                    if let Some(tx) = self.peers[w].tx.as_ref() {
                        let _ = tx.send(frame);
                    }
                }
            }
        }
        for p in &mut self.peers {
            p.tx = None; // close the write queues; writers drain and exit
            if let Some(j) = p.writer.take() {
                let _ = j.join();
            }
            if let Some(j) = p.reader.take() {
                let _ = j.join();
            }
        }
    }

    /// A cleared payload staging buffer from the shared pool (falls back
    /// to a fresh `Vec` when a writer thread holds the pool lock — the
    /// pool is an optimization, never a correctness dependency).
    fn take_buf(&self) -> Vec<u8> {
        match self.pool.try_lock() {
            Ok(mut p) => p.take(),
            Err(_) => Vec::new(),
        }
    }

    /// Cumulative hit/miss/drop counters of the wire payload pool.
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.lock().map(|p| p.stats()).unwrap_or_default()
    }

    /// Encode a leader command into its wire frame, billing the payload
    /// per the accounting rules (DESIGN.md §4): `SyncStep` pushes and
    /// `InstallState` pulls are billed; control frames, `Eval` payloads
    /// and raw collects are free.
    fn cmd_to_frame(&mut self, w: usize, cmd: Cmd) -> Result<Frame> {
        let worker = w as u32;
        Ok(match cmd {
            Cmd::SyncStep { t, x, scratch: _ } => {
                let mut payload = self.take_buf();
                let mut wd = lock(&self.state);
                // bf16 wire: ship the bf16 image (x is already on the
                // grid after the collective's broadcast). QSGD ships the
                // dense f32 model — the leader owns x, and the pull is
                // billed at 4 bytes/element, exactly as in-process.
                let codec_tag = if matches!(wd.codec, PayloadCodec::Bf16) {
                    wd.codec.encode_vec(0, &x, &mut payload);
                    wd.codec.tag()
                } else {
                    put_f32s(&mut payload, &x);
                    CODEC_RAW
                };
                drop(wd);
                self.counters.add_accounted(payload.len() as u64);
                Frame {
                    kind: FrameKind::SyncStep,
                    codec: codec_tag,
                    flags: 0,
                    worker,
                    step: t,
                    payload,
                }
            }
            Cmd::LocalStep { t, lr } => Frame {
                kind: FrameKind::LocalStep,
                codec: CODEC_RAW,
                flags: 0,
                worker,
                step: t,
                payload: lr.to_le_bytes().to_vec(),
            },
            Cmd::CollectState { raw, .. } => Frame {
                kind: FrameKind::CollectState,
                codec: CODEC_RAW,
                flags: if raw { FLAG_RAW } else { 0 },
                worker,
                step: 0,
                payload: Vec::new(),
            },
            Cmd::InstallState { x, acc } => {
                let mut p = self.take_buf();
                let mut wd = lock(&self.state);
                let (payload, tag) = if wd.codec.is_f32() {
                    put_f32s(&mut p, &x);
                    if let Some(a) = acc.as_deref() {
                        put_f32s(&mut p, a);
                    }
                    (p, CODEC_RAW)
                } else {
                    // Lossy codecs install the encoded down-leg deltas the
                    // sync round staged — the exact bytes the collective
                    // billed.
                    let tag = wd.codec.tag();
                    let stash = wd.install.as_mut().ok_or_else(|| {
                        Error::Protocol(
                            "InstallState without a staged sync round over the networked \
                             transport"
                                .into(),
                        )
                    })?;
                    p.extend_from_slice(&stash.payload);
                    stash.remaining = stash.remaining.saturating_sub(1);
                    if stash.remaining == 0 {
                        wd.install = None;
                    }
                    (p, tag)
                };
                drop(wd);
                self.counters.add_accounted(payload.len() as u64);
                Frame {
                    kind: FrameKind::InstallState,
                    codec: tag,
                    flags: 0,
                    worker,
                    step: 0,
                    payload,
                }
            }
            Cmd::Eval { x } => {
                let mut payload = Vec::new();
                match x.as_deref() {
                    Some(v) => {
                        payload.push(1);
                        put_f32s(&mut payload, v);
                    }
                    None => payload.push(0),
                }
                // Observer-only: exact f32, unbilled (matches the
                // in-process accounting, which books nothing for evals).
                Frame {
                    kind: FrameKind::Eval,
                    codec: CODEC_RAW,
                    flags: FLAG_RAW,
                    worker,
                    step: 0,
                    payload,
                }
            }
            Cmd::Stop => Frame::control(FrameKind::Stop, worker, 0),
        })
    }

    /// Expand a leader command frame into its wire frames: sync-round
    /// `InstallState` payloads are split into one shard-tagged frame per
    /// leader shard (`comm.shards`; each shard server broadcasts its own
    /// averaged range); every other frame — and every frame on the dense
    /// plan — ships as-is, byte-identical to the pre-sharding wire.
    fn shard_install_frames(&self, frame: Frame) -> Result<Vec<Frame>> {
        if frame.kind != FrameKind::InstallState {
            return Ok(vec![frame]);
        }
        let (plan, elem) = {
            let wd = lock(&self.state);
            (wd.plan.clone(), wd.codec.enc_len(1))
        };
        if plan.is_dense() {
            return Ok(vec![frame]);
        }
        let payloads = split_state_payload(&frame.payload, elem, &plan)?;
        Ok(payloads
            .into_iter()
            .enumerate()
            .map(|(s, payload)| Frame {
                kind: frame.kind,
                codec: frame.codec,
                flags: frame.flags | shard_flags(s),
                worker: frame.worker,
                step: frame.step,
                payload,
            })
            .collect())
    }

    /// Decode a worker frame into the protocol reply. Shard-tagged
    /// `State` frames are folded into the per-worker reassembly and
    /// return `None` until their last shard lands (TCP FIFO keeps them
    /// in shard order); everything else decodes immediately.
    fn frame_to_reply(&mut self, w: usize, mut f: Frame) -> Result<Option<Reply>> {
        if f.kind == FrameKind::State && f.flags & FLAG_RAW == 0 {
            let (plan, elem) = {
                let wd = lock(&self.state);
                (wd.plan.clone(), wd.codec.enc_len(1))
            };
            if !plan.is_dense() {
                if f.worker as usize != w {
                    return Err(Error::Protocol(format!(
                        "frame from peer {w} claims worker id {}",
                        f.worker
                    )));
                }
                match self.assembly[w].push(&plan, elem, flags_shard(f.flags), &f.payload)? {
                    Some(dense) => {
                        f.payload = dense;
                        f.flags &= FLAG_RAW; // drop the shard tag
                    }
                    None => return Ok(None),
                }
            }
        }
        self.frame_to_reply_dense(w, f).map(Some)
    }

    /// Decode a (dense or reassembled) worker frame into the protocol
    /// reply, billing per the accounting rules: `Grad` payloads (minus
    /// the loss scalar) and non-raw `State` collects are billed.
    fn frame_to_reply_dense(&mut self, w: usize, f: Frame) -> Result<Reply> {
        if f.worker as usize != w {
            return Err(Error::Protocol(format!(
                "frame from peer {w} claims worker id {}",
                f.worker
            )));
        }
        Ok(match f.kind {
            FrameKind::Grad => {
                if f.payload.len() < 4 {
                    return Err(Error::Protocol("Grad frame too short".into()));
                }
                let loss = f32::from_le_bytes(f.payload[..4].try_into().expect("sized"));
                let enc = &f.payload[4..];
                self.counters.add_accounted(enc.len() as u64);
                let mut wd = lock(&self.state);
                let mut grad = vec![0.0f32; wd.d];
                wd.codec.decode_vec(enc, &mut grad)?;
                Reply::Grad { worker: w, loss, grad }
            }
            FrameKind::StepDone => {
                if f.payload.len() != 12 {
                    return Err(Error::Protocol("StepDone frame malformed".into()));
                }
                let loss = f32::from_le_bytes(f.payload[..4].try_into().expect("sized"));
                let update_sq = f64::from_le_bytes(f.payload[4..12].try_into().expect("sized"));
                Reply::StepDone { worker: w, loss, update_sq }
            }
            FrameKind::State => {
                let mut wd = lock(&self.state);
                let d = wd.d;
                if f.flags & FLAG_RAW != 0 {
                    // Observer collect: exact f32, unbilled.
                    let (x, acc) = split_raw_state(&f.payload, d)?;
                    Reply::State { worker: w, x, acc }
                } else if wd.codec.is_f32() {
                    self.counters.add_accounted(f.payload.len() as u64);
                    let (x, acc) = split_raw_state(&f.payload, d)?;
                    Reply::State { worker: w, x, acc }
                } else {
                    self.counters.add_accounted(f.payload.len() as u64);
                    let enc_len = wd.codec.enc_len(d);
                    let (ex, ea) = split_enc_state(&f.payload, enc_len)?;
                    let mut dx = vec![0.0f32; d];
                    wd.codec.decode_vec(ex, &mut dx)?;
                    let mut x = vec![0.0f32; d];
                    kernels::delta_decode(&wd.base_x, &dx, &mut x);
                    wd.pending_x[w] = Some(dx);
                    let acc = match ea {
                        Some(ea) => {
                            let mut da = vec![0.0f32; d];
                            wd.codec.decode_vec(ea, &mut da)?;
                            let mut a = vec![0.0f32; d];
                            kernels::delta_decode(&wd.base_acc, &da, &mut a);
                            wd.pending_acc[w] = Some(da);
                            Some(a)
                        }
                        None => None,
                    };
                    Reply::State { worker: w, x, acc }
                }
            }
            FrameKind::EvalDone => {
                if f.payload.len() != 17 {
                    return Err(Error::Protocol("EvalDone frame malformed".into()));
                }
                let loss = f64::from_le_bytes(f.payload[..8].try_into().expect("sized"));
                let ppl = (f.payload[8] != 0)
                    .then(|| f64::from_le_bytes(f.payload[9..17].try_into().expect("sized")));
                Reply::Eval { worker: w, metrics: EvalMetrics { loss, ppl } }
            }
            FrameKind::Ready => Reply::Ready { worker: w },
            FrameKind::Crashed => Reply::Crashed { worker: w, step: f.step },
            FrameKind::Leave => {
                // Voluntary departure: the peer's trailing EOF is now
                // expected and must not synthesize a crash tombstone.
                self.left[w] = true;
                Reply::Left { worker: w, step: f.step }
            }
            FrameKind::ErrMsg => Reply::Err {
                worker: w,
                msg: String::from_utf8_lossy(&f.payload).into_owned(),
            },
            other => {
                return Err(Error::Protocol(format!(
                    "unexpected {other:?} frame from worker {w}"
                )))
            }
        })
    }
}

// ---------------------------------------------------------------------------
// LeaderLink — one trainer-facing surface over both transports.
// ---------------------------------------------------------------------------

/// The transport the trainer drives: in-process channels or real sockets,
/// same methods, same error wording. The gather algorithms are written
/// once here against [`LeaderLink::recv`], mirroring
/// [`ChannelTransport::gather`]/[`gather_from`](ChannelTransport::gather_from)
/// exactly.
pub enum LeaderLink {
    /// In-process mpsc channels ([`ChannelTransport`]) — the oracle.
    Chan(ChannelTransport<Cmd, Reply>),
    /// Real TCP / Unix-domain sockets.
    Net(Box<TcpTransport>),
}

impl LeaderLink {
    /// Number of workers.
    pub fn n(&self) -> usize {
        match self {
            LeaderLink::Chan(t) => t.n(),
            LeaderLink::Net(t) => t.n(),
        }
    }

    /// Send `make(w)` to every worker.
    pub fn broadcast(&mut self, make: impl FnMut(usize) -> Cmd) -> Result<()> {
        match self {
            LeaderLink::Chan(t) => t.broadcast(make),
            LeaderLink::Net(t) => t.broadcast(make),
        }
    }

    /// Send `make(w)` to each worker in `targets`.
    pub fn broadcast_to(
        &mut self,
        targets: &[usize],
        make: impl FnMut(usize) -> Cmd,
    ) -> Result<()> {
        match self {
            LeaderLink::Chan(t) => t.broadcast_to(targets, make),
            LeaderLink::Net(t) => t.broadcast_to(targets, make),
        }
    }

    /// Send one command to a single worker.
    pub fn send_to(&mut self, w: usize, cmd: Cmd) -> Result<()> {
        match self {
            LeaderLink::Chan(t) => t.send_to(w, cmd),
            LeaderLink::Net(t) => t.send_to(w, cmd),
        }
    }

    /// Worker ids with a late wire handshake awaiting admission. Always
    /// empty in-process: channel cells never reconnect — plan rejoins
    /// revive them directly via `InstallState`.
    pub fn poll_joins(&self) -> Vec<usize> {
        match self {
            LeaderLink::Chan(_) => Vec::new(),
            LeaderLink::Net(t) => t.poll_joins(),
        }
    }

    /// Admit a parked late handshake ([`TcpTransport::admit_join`]).
    pub fn admit_join(&mut self, w: usize) -> Result<()> {
        match self {
            LeaderLink::Chan(_) => Err(Error::Protocol(format!(
                "admit_join({w}) over the in-process transport (no wire, no late handshakes)"
            ))),
            LeaderLink::Net(t) => t.admit_join(w),
        }
    }

    /// Is worker `w`'s connection dead at the transport level? Always
    /// false in-process (channel cells outlive their scheduled crashes
    /// and can be revived; there is no socket to lose).
    pub fn peer_dead(&self, w: usize) -> bool {
        match self {
            LeaderLink::Chan(_) => false,
            LeaderLink::Net(t) => t.peer_dead(w),
        }
    }

    /// Receive the next reply from any worker.
    pub fn recv(&mut self) -> Result<Reply> {
        match self {
            LeaderLink::Chan(t) => t.recv(),
            LeaderLink::Net(t) => t.recv(),
        }
    }

    /// Gather exactly one reply per worker ([`ChannelTransport::gather`]).
    pub fn gather<T>(
        &mut self,
        mut sel: impl FnMut(Reply) -> Result<(usize, T)>,
    ) -> Result<Vec<T>> {
        let n = self.n();
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut got = 0;
        while got < n {
            let (w, v) = sel(self.recv()?)?;
            let slot = out
                .get_mut(w)
                .ok_or_else(|| Error::Protocol(format!("reply from unknown worker {w}")))?;
            if slot.replace(v).is_some() {
                return Err(Error::Protocol(format!("duplicate reply from worker {w}")));
            }
            got += 1;
        }
        Ok(out.into_iter().map(|v| v.expect("filled")).collect())
    }

    /// Gather one reply from each worker in `targets`, in target order
    /// ([`ChannelTransport::gather_from`]).
    pub fn gather_from<T>(
        &mut self,
        targets: &[usize],
        mut sel: impl FnMut(Reply) -> Result<(usize, T)>,
    ) -> Result<Vec<T>> {
        let mut slot_of: Vec<Option<usize>> = vec![None; self.n()];
        for (i, &w) in targets.iter().enumerate() {
            let slot = slot_of
                .get_mut(w)
                .ok_or_else(|| Error::Protocol(format!("no worker {w}")))?;
            if slot.replace(i).is_some() {
                return Err(Error::Protocol(format!("duplicate gather target {w}")));
            }
        }
        let mut out: Vec<Option<T>> = (0..targets.len()).map(|_| None).collect();
        let mut got = 0;
        while got < targets.len() {
            let (w, v) = sel(self.recv()?)?;
            let slot = slot_of
                .get(w)
                .copied()
                .flatten()
                .ok_or_else(|| Error::Protocol(format!("unexpected reply from worker {w}")))?;
            if out[slot].replace(v).is_some() {
                return Err(Error::Protocol(format!("duplicate reply from worker {w}")));
            }
            got += 1;
        }
        Ok(out.into_iter().map(|v| v.expect("filled")).collect())
    }

    /// Best-effort shutdown (both transports swallow errors).
    pub fn shutdown(&mut self, stop: impl FnMut(usize) -> Cmd) {
        match self {
            LeaderLink::Chan(t) => t.shutdown(stop),
            LeaderLink::Net(t) => t.shutdown(stop),
        }
    }
}

// ---------------------------------------------------------------------------
// WireCollective — lossy codecs over the real wire.
// ---------------------------------------------------------------------------

/// The leader's [`Collective`] for bf16/QSGD payloads over the networked
/// transport. The up-leg deltas were decoded off the actual socket frames
/// (staged in [`WireState`] by the transport); this op averages them,
/// encodes the down leg once per vector family (burning the same
/// `(seed, stream, use)` RNG the in-process codec would), stages the
/// encoded bytes for the `InstallState` frames, and bills exactly what
/// the in-process [`CompressedCollective`](super::CompressedCollective)
/// bills — which is also exactly what crosses the socket.
pub struct WireCollective {
    state: Arc<Mutex<WireState>>,
    net: NetModel,
    inner_label: String,
    is_bf16: bool,
    /// Leader-side reduction executor: serial by default, fanned over
    /// `min(pipeline, shards)` scoped threads when `[comm] pipeline ≥ 2`
    /// on a sharded plan (bitwise-identical, see [`mean_into_sharded_exec`]).
    exec: Executor,
    /// Configured `[comm] pipeline` depth (0 = off).
    pipeline: usize,
    mean_buf: Vec<f32>,
    hat_buf: Vec<f32>,
    enc_buf: Vec<u8>,
}

impl WireCollective {
    /// Wrap the shared wire state with the α–β model used for virtual
    /// time; `inner_label` names the codec ("bf16", "qsgd(s=15)").
    pub fn new(state: Arc<Mutex<WireState>>, net: NetModel, inner_label: String) -> Self {
        let is_bf16 = matches!(lock(&state).codec, PayloadCodec::Bf16);
        WireCollective {
            state,
            net,
            inner_label,
            is_bf16,
            exec: Executor::serial(),
            pipeline: 0,
            mean_buf: Vec::new(),
            hat_buf: Vec::new(),
            enc_buf: Vec::new(),
        }
    }

    /// Apply the `[comm] pipeline` knob: depth ≥ 2 on a sharded plan fans
    /// the sync-round reduction over scoped threads; anything else keeps
    /// the serial executor (`depth = 1` ≡ off by construction).
    pub fn with_pipeline(mut self, depth: usize) -> Self {
        let shards = lock(&self.state).plan.shards();
        self.exec = if depth >= 2 && shards > 1 {
            Executor::threads(depth.min(shards))
        } else {
            Executor::serial()
        };
        self.pipeline = depth;
        self
    }
}

/// Average one vector family's pending deltas, encode/decode the down
/// leg, advance the base, and return the billed bytes (up + down legs).
fn family_round(
    wd: &mut WireState,
    exec: &Executor,
    family: StreamFamily,
    out: &mut [f32],
    payload: &mut Vec<u8>,
    mean: &mut Vec<f32>,
    hat: &mut Vec<f32>,
) -> Result<u64> {
    let (n, d) = (wd.n, wd.d);
    let plan = wd.plan.clone();
    {
        let pend = match family {
            StreamFamily::SyncX => &mut wd.pending_x,
            StreamFamily::SyncAcc => &mut wd.pending_acc,
            StreamFamily::Raw => unreachable!("no Raw family over the wire"),
        };
        let mut deltas: Vec<&[f32]> = Vec::with_capacity(n);
        for (w, p) in pend.iter().enumerate() {
            deltas.push(p.as_deref().ok_or_else(|| {
                Error::Protocol(format!(
                    "sync round without worker {w}'s state over the networked transport"
                ))
            })?);
        }
        mean.resize(d, 0.0);
        if !plan.is_dense() && !matches!(exec.parallelism(), Parallelism::Serial) {
            // Pipelined leader: reduce the shard ranges in parallel —
            // bitwise-identical to the dense mean (pinned in comm::shard).
            mean_into_sharded_exec(&plan, exec, &deltas, mean);
        } else {
            kernels::mean_into(&deltas, mean);
        }
        for p in pend.iter_mut() {
            *p = None;
        }
    }
    // Up leg: the per-worker encoded deltas already shipped (billed here,
    // counted on the socket by the transport — sizes are deterministic).
    let mut bytes = n as u64 * wd.codec.enc_len(d) as u64;
    let start = payload.len();
    wd.codec.encode_vec(down_stream(n, family), mean, payload);
    let enc = payload.len() - start;
    bytes += n as u64 * enc as u64;
    hat.resize(d, 0.0);
    wd.codec.decode_vec(&payload[start..], hat)?;
    match family {
        StreamFamily::SyncX => {
            kernels::delta_decode(&wd.base_x, hat, out);
            wd.base_x.copy_from_slice(out);
        }
        StreamFamily::SyncAcc => {
            kernels::delta_decode_clamped(&wd.base_acc, hat, out);
            wd.base_acc.copy_from_slice(out);
        }
        StreamFamily::Raw => unreachable!(),
    }
    Ok(bytes)
}

impl Collective for WireCollective {
    fn n(&self) -> usize {
        lock(&self.state).n
    }

    fn label(&self) -> String {
        let wd = lock(&self.state);
        let pipe = if self.pipeline > 0 {
            format!("+pipe({})", self.pipeline)
        } else {
            String::new()
        };
        if wd.plan.is_dense() {
            format!("net({}){pipe}", self.inner_label)
        } else {
            format!("net({}, shards={}){pipe}", self.inner_label, wd.plan.shards())
        }
    }

    fn broadcast(&mut self, x: &mut [f32]) -> Result<CommReport> {
        // Same contract as the in-process bf16 wire: the broadcast model
        // is rounded onto the bf16 grid (that is what the frames carry);
        // billed free, the pull leg is accounted by the round op.
        if self.is_bf16 {
            crate::util::half::quantize_assign(x);
        }
        Ok(CommReport::zero())
    }

    fn gather_grads(&mut self, grads: &mut [Vec<f32>]) -> Result<CommReport> {
        let wd = lock(&self.state);
        let (n, d) = (wd.n, wd.d);
        if grads.len() != n {
            return Err(Error::Protocol(format!(
                "gather_grads: {} gradients for {n} workers",
                grads.len()
            )));
        }
        for (w, g) in grads.iter().enumerate() {
            if g.len() != d {
                return Err(Error::Protocol(format!(
                    "gather_grads: worker {w} gradient len {} != d {d}",
                    g.len()
                )));
            }
        }
        // The gradients were decoded off the wire — already the
        // decode(encode(·)) images the in-process codec produces. Bill
        // the identical round: Σ enc(g_i) up, dense model pull down.
        let pull = if self.is_bf16 { 2u64 } else { 4u64 };
        let bytes = n as u64 * wd.codec.enc_len(d) as u64 + n as u64 * pull * d as u64;
        drop(wd);
        Ok(CommReport {
            bytes,
            time_s: self.net.bytes_time(n, bytes),
            rounds: 1,
            drift_sq: 0.0,
            straggler_s: self.net.straggler_spread_s(n, bytes / (2 * n as u64)),
        })
    }

    fn allreduce_mean(&mut self, _inputs: &[&[f32]], _out: &mut [f32]) -> Result<CommReport> {
        Err(Error::Protocol(
            "allreduce_mean is not supported over the networked transport".into(),
        ))
    }

    fn sync_round(
        &mut self,
        xs: &[&[f32]],
        accs: Option<&[&[f32]]>,
        avg_x: &mut [f32],
        avg_acc: Option<&mut [f32]>,
    ) -> Result<CommReport> {
        if accs.is_some() != avg_acc.is_some() {
            return Err(Error::Protocol(
                "sync_round: accs and avg_acc must both be present or both absent".into(),
            ));
        }
        let mut wd = lock(&self.state);
        let n = wd.n;
        if xs.len() != n {
            return Err(Error::Protocol(format!(
                "sync_round: {} states for {n} workers (partial rounds require the \
                 dense f32 wire over tcp/uds)",
                xs.len()
            )));
        }
        self.enc_buf.clear();
        let mut bytes = family_round(
            &mut wd,
            &self.exec,
            StreamFamily::SyncX,
            avg_x,
            &mut self.enc_buf,
            &mut self.mean_buf,
            &mut self.hat_buf,
        )?;
        // Drift against the installed average, from the leader's
        // post-roundtrip reconstructions (see the module docs).
        let drift_sq = mean_sq_dist(xs, avg_x);
        if let (Some(_), Some(avg_acc)) = (accs, avg_acc) {
            bytes += family_round(
                &mut wd,
                &self.exec,
                StreamFamily::SyncAcc,
                avg_acc,
                &mut self.enc_buf,
                &mut self.mean_buf,
                &mut self.hat_buf,
            )?;
        }
        wd.install = Some(InstallStash { payload: self.enc_buf.clone(), remaining: n });
        drop(wd);
        Ok(CommReport {
            bytes,
            time_s: self.net.bytes_time(n, bytes),
            rounds: 1,
            drift_sq,
            straggler_s: self.net.straggler_spread_s(n, bytes / (2 * n as u64)),
        })
    }
}

// ---------------------------------------------------------------------------
// run_worker — the worker process body.
// ---------------------------------------------------------------------------

/// The worker-process shim state: mirrored delta bases and the codec with
/// its per-stream use counters — exactly the sequence of encodes the
/// in-process codec performs for this worker's streams.
struct WorkerShim {
    codec: PayloadCodec,
    n: usize,
    w: usize,
    d: usize,
    /// Leader-shard range partition — computed from `(d, comm.shards)`
    /// independently of the leader (the fingerprint pins the shard count).
    plan: ShardPlan,
    /// Reassembly of shard-tagged `InstallState` frames.
    install: ShardAssembly,
    base_x: Vec<f32>,
    base_acc: Vec<f32>,
    /// Raw-collect flag of the `CollectState` in flight (the matching
    /// `State` reply ships raw f32 when set).
    collect_raw: bool,
    /// Step of the command in flight (stamped on reply frames).
    step: u64,
    scratch: Vec<f32>,
}

impl WorkerShim {
    /// Decode a leader frame into the cell command. Shard-tagged
    /// `InstallState` frames fold into the reassembly and return `None`
    /// until the last shard lands; everything else decodes immediately.
    fn frame_to_cmd(&mut self, f: &Frame, exit_at: Option<u64>) -> Result<Option<Cmd>> {
        let d = self.d;
        self.step = f.step;
        Ok(Some(match f.kind {
            FrameKind::SyncStep => {
                if exit_at == Some(f.step) {
                    std::process::exit(3);
                }
                let x = match f.codec {
                    wire::CODEC_BF16 => {
                        let mut v = vec![0.0f32; d];
                        PayloadCodec::Bf16.decode_vec(&f.payload, &mut v)?;
                        v
                    }
                    _ => get_f32s(&f.payload, d)?,
                };
                Cmd::SyncStep { t: f.step, x: Arc::new(x), scratch: Vec::new() }
            }
            FrameKind::LocalStep => {
                if exit_at == Some(f.step) {
                    std::process::exit(3);
                }
                if f.payload.len() != 4 {
                    return Err(Error::Protocol("LocalStep frame malformed".into()));
                }
                let lr = f32::from_le_bytes(f.payload[..4].try_into().expect("sized"));
                Cmd::LocalStep { t: f.step, lr }
            }
            FrameKind::CollectState => {
                self.collect_raw = f.flags & FLAG_RAW != 0;
                Cmd::CollectState { sx: Vec::new(), sa: Vec::new(), raw: self.collect_raw }
            }
            FrameKind::InstallState => {
                let assembled;
                let payload: &[u8] = if self.plan.is_dense() {
                    &f.payload
                } else {
                    // Shard-tagged install: each frame carries one shard
                    // server's averaged range; reassemble to the dense
                    // payload (byte-identical to the unsharded wire).
                    match self.install.push(
                        &self.plan,
                        self.codec.enc_len(1),
                        flags_shard(f.flags),
                        &f.payload,
                    )? {
                        Some(p) => {
                            assembled = p;
                            &assembled
                        }
                        None => return Ok(None),
                    }
                };
                let (x, acc) = if self.codec.is_f32() {
                    split_raw_state(payload, d)?
                } else {
                    // Encoded down-leg deltas: reconstruct against the
                    // mirrored bases, then advance them — the same values
                    // the leader installed in its own avg buffers.
                    let enc_len = self.codec.enc_len(d);
                    let (ex, ea) = split_enc_state(payload, enc_len)?;
                    self.scratch.resize(d, 0.0);
                    self.codec.decode_vec(ex, &mut self.scratch)?;
                    let mut x = vec![0.0f32; d];
                    kernels::delta_decode(&self.base_x, &self.scratch, &mut x);
                    self.base_x.copy_from_slice(&x);
                    let acc = match ea {
                        Some(ea) => {
                            self.codec.decode_vec(ea, &mut self.scratch)?;
                            let mut a = vec![0.0f32; d];
                            kernels::delta_decode_clamped(&self.base_acc, &self.scratch, &mut a);
                            self.base_acc.copy_from_slice(&a);
                            Some(a)
                        }
                        None => None,
                    };
                    (x, acc)
                };
                Cmd::InstallState { x: Arc::new(x), acc: acc.map(Arc::new) }
            }
            FrameKind::Eval => {
                if f.payload.is_empty() {
                    return Err(Error::Protocol("Eval frame malformed".into()));
                }
                let x = match f.payload[0] {
                    0 => None,
                    _ => Some(Arc::new(get_f32s(&f.payload[1..], d)?)),
                };
                Cmd::Eval { x }
            }
            FrameKind::Stop => Cmd::Stop,
            other => {
                return Err(Error::Protocol(format!(
                    "unexpected {other:?} frame from the leader"
                )))
            }
        }))
    }

    /// Encode a cell reply into its wire frames: sync-round `State`
    /// collects are split into one shard-tagged frame per leader shard
    /// (the worker pushes each range to its shard server); everything
    /// else — and everything on the dense plan — is a single frame,
    /// byte-identical to the pre-sharding wire.
    fn reply_to_frames(&mut self, reply: Reply) -> Result<Vec<Frame>> {
        let frame = self.reply_to_frame(reply);
        if frame.kind == FrameKind::State
            && frame.flags & FLAG_RAW == 0
            && !self.plan.is_dense()
        {
            let payloads =
                split_state_payload(&frame.payload, self.codec.enc_len(1), &self.plan)?;
            return Ok(payloads
                .into_iter()
                .enumerate()
                .map(|(s, payload)| Frame {
                    kind: frame.kind,
                    codec: frame.codec,
                    flags: frame.flags | shard_flags(s),
                    worker: frame.worker,
                    step: frame.step,
                    payload,
                })
                .collect());
        }
        Ok(vec![frame])
    }

    fn reply_to_frame(&mut self, reply: Reply) -> Frame {
        let worker = self.w as u32;
        let step = self.step;
        match reply {
            Reply::Ready { .. } => Frame::control(FrameKind::Ready, worker, step),
            Reply::Crashed { step: s, .. } => Frame::control(FrameKind::Crashed, worker, s),
            Reply::Left { step: s, .. } => Frame::control(FrameKind::Leave, worker, s),
            Reply::Err { msg, .. } => Frame {
                kind: FrameKind::ErrMsg,
                codec: CODEC_RAW,
                flags: 0,
                worker,
                step,
                payload: msg.into_bytes(),
            },
            Reply::Grad { loss, grad, .. } => {
                let mut payload = Vec::with_capacity(4 + self.codec.enc_len(grad.len()));
                payload.extend_from_slice(&loss.to_le_bytes());
                match &mut self.codec {
                    PayloadCodec::F32 => put_f32s(&mut payload, &grad),
                    codec => codec.encode_vec(grad_stream(self.w), &grad, &mut payload),
                }
                Frame {
                    kind: FrameKind::Grad,
                    codec: self.codec.tag(),
                    flags: 0,
                    worker,
                    step,
                    payload,
                }
            }
            Reply::StepDone { loss, update_sq, .. } => {
                let mut payload = Vec::with_capacity(12);
                payload.extend_from_slice(&loss.to_le_bytes());
                payload.extend_from_slice(&update_sq.to_le_bytes());
                Frame {
                    kind: FrameKind::StepDone,
                    codec: CODEC_RAW,
                    flags: 0,
                    worker,
                    step,
                    payload,
                }
            }
            Reply::State { x, acc, .. } => {
                let mut payload = Vec::new();
                let (tag, flags) = if self.collect_raw || self.codec.is_f32() {
                    put_f32s(&mut payload, &x);
                    if let Some(a) = &acc {
                        put_f32s(&mut payload, a);
                    }
                    (CODEC_RAW, if self.collect_raw { FLAG_RAW } else { 0 })
                } else {
                    // Sync-round collect: ship encoded deltas against the
                    // mirrored bases, burning this worker's up-stream RNG
                    // uses exactly as the in-process codec does.
                    self.scratch.resize(self.d, 0.0);
                    kernels::delta_encode(&x, &self.base_x, &mut self.scratch);
                    let stream = up_stream(self.n, StreamFamily::SyncX, self.w);
                    let scratch = std::mem::take(&mut self.scratch);
                    self.codec.encode_vec(stream, &scratch, &mut payload);
                    self.scratch = scratch;
                    if let Some(a) = &acc {
                        kernels::delta_encode(a, &self.base_acc, &mut self.scratch);
                        let stream = up_stream(self.n, StreamFamily::SyncAcc, self.w);
                        let scratch = std::mem::take(&mut self.scratch);
                        self.codec.encode_vec(stream, &scratch, &mut payload);
                        self.scratch = scratch;
                    }
                    (self.codec.tag(), 0)
                };
                Frame { kind: FrameKind::State, codec: tag, flags, worker, step, payload }
            }
            Reply::Eval { metrics, .. } => {
                let mut payload = Vec::with_capacity(17);
                payload.extend_from_slice(&metrics.loss.to_le_bytes());
                payload.push(metrics.ppl.is_some() as u8);
                payload.extend_from_slice(&metrics.ppl.unwrap_or(0.0).to_le_bytes());
                Frame {
                    kind: FrameKind::EvalDone,
                    codec: CODEC_RAW,
                    flags: 0,
                    worker,
                    step,
                    payload,
                }
            }
        }
    }
}

/// Resolve the leader address a worker process should dial: the port
/// file (polled — the leader publishes its port-0 bind there) wins, then
/// `--connect`, then `[net] connect`.
pub fn resolve_connect_addr(
    cfg: &ExperimentConfig,
    connect_flag: &str,
    port_file: Option<&str>,
) -> Result<String> {
    let timeout = Duration::from_secs_f64(cfg.net.connect_timeout_s);
    if let Some(pf) = port_file {
        return read_port_file(pf, timeout);
    }
    let addr = if connect_flag.is_empty() { cfg.net.connect.as_str() } else { connect_flag };
    if addr.is_empty() {
        return Err(Error::Config(
            "net.connect: no leader address (set [net] connect, --connect or --port-file)"
                .into(),
        ));
    }
    Ok(addr.to_string())
}

/// Cap on a single connect-retry sleep. Also the saturation value when
/// `base × attempt` would overflow a `Duration` (`Duration * u32` panics
/// on overflow — a huge `net.retry_backoff_s` must not crash the worker).
const MAX_RETRY_BACKOFF: Duration = Duration::from_secs(30);

/// Linear backoff for connect attempt `attempt` (1-based), overflow-safe
/// and capped at [`MAX_RETRY_BACKOFF`].
fn retry_backoff(base: Duration, attempt: u32) -> Duration {
    base.checked_mul(attempt).unwrap_or(MAX_RETRY_BACKOFF).min(MAX_RETRY_BACKOFF)
}

fn connect_with_retry(cfg: &ExperimentConfig, kind: SocketKind, addr: &str) -> Result<NetStream> {
    let retries = cfg.net.connect_retries;
    let backoff = Duration::from_secs_f64(cfg.net.retry_backoff_s.max(0.0));
    let mut attempt = 0u32;
    loop {
        match NetStream::connect(kind, addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                attempt += 1;
                if attempt > retries {
                    // Returns before any further sleep: the final failed
                    // attempt reports immediately instead of serving one
                    // last pointless backoff.
                    return Err(Error::Config(format!(
                        "net.connect: could not reach the leader at {addr:?} after \
                         {attempt} attempts (net.connect_retries = {retries}, \
                         net.retry_backoff_s = {}): {e}",
                        cfg.net.retry_backoff_s
                    )));
                }
                std::thread::sleep(retry_backoff(backoff, attempt));
            }
        }
    }
}

/// The `--role worker` process body: connect to the leader (retrying per
/// the `[net]` budget), handshake, spawn the unchanged [`worker_loop`]
/// cell, and shim frames ⇄ commands until `Stop`.
///
/// With `rejoin` set (`--rejoin`), the handshake opens with a `Join`
/// frame instead of `Hello`: a relaunched worker announcing itself to a
/// live run. The leader parks the connection and answers the `HelloAck`
/// only when it admits the worker at the next sync-round boundary, so
/// the ack wait can span a local phase.
///
/// The cell, backends, kernels and codec draws are byte-for-byte the
/// in-process ones — the only new code on this path is (de)framing.
pub fn run_worker(
    cfg: &ExperimentConfig,
    worker: usize,
    connect_flag: &str,
    port_file: Option<&str>,
    rejoin: bool,
) -> Result<()> {
    crate::util::simd::set_mode(crate::util::simd::SimdMode::from_config(&cfg.exec)?);
    let kind = SocketKind::from_transport(&cfg.comm.transport).ok_or_else(|| {
        Error::Config(format!(
            "comm.transport must be \"tcp\" or \"uds\" for --role worker, got {:?}",
            cfg.comm.transport
        ))
    })?;
    let addr = resolve_connect_addr(cfg, connect_flag, port_file)?;
    let mut stream = connect_with_retry(cfg, kind, &addr)?;
    stream.set_nodelay(cfg.net.nodelay);

    // Handshake: Hello for the initial roll call, Join for a relaunched
    // worker rejoining a live run (same payload, same validation).
    let fp = wire::config_fingerprint(cfg);
    Frame {
        kind: if rejoin { FrameKind::Join } else { FrameKind::Hello },
        codec: CODEC_RAW,
        flags: 0,
        worker: worker as u32,
        step: PROTOCOL_VERSION as u64,
        payload: fp.to_le_bytes().to_vec(),
    }
    .write_to(&mut stream)?;
    stream.set_read_timeout(Some(Duration::from_secs_f64(cfg.net.connect_timeout_s)));
    let ack = match Frame::read_from(&mut stream)? {
        Some(f) if f.kind == FrameKind::HelloAck => decode_hello_ack(&f.payload)?,
        Some(f) if f.kind == FrameKind::ErrMsg => {
            return Err(Error::Config(format!(
                "handshake rejected: {}",
                String::from_utf8_lossy(&f.payload)
            )))
        }
        Some(f) => {
            return Err(Error::Protocol(format!(
                "expected HelloAck, got {:?}",
                f.kind
            )))
        }
        None => return Err(Error::Protocol("leader closed the connection during handshake".into())),
    };
    stream.set_read_timeout(None);
    let d = ack.init.len();

    // The worker cell — the exact in-process body on a thread.
    let spec = WorkerSpec {
        worker,
        algorithm: cfg.optim.algorithm,
        epsilon: cfg.optim.epsilon,
        b0: cfg.optim.b0,
        init: Arc::new(ack.init),
        allow_fused: ack.allow_fused,
        collect_update_sq: ack.collect_update_sq,
        bf16_state: ack.bf16_state,
        crash_step: ack.crash_step,
    };
    let factory = make_factory(cfg)?;
    let (cmd_tx, cmd_rx) = std::sync::mpsc::channel::<Cmd>();
    let (reply_tx, reply_rx) = std::sync::mpsc::channel::<Reply>();
    let cell = std::thread::spawn(move || worker_loop(spec, factory, cmd_rx, reply_tx));

    let exit_at: Option<u64> =
        std::env::var(EXIT_AT_STEP_ENV).ok().and_then(|v| v.parse().ok());
    let leave_at: Option<u64> =
        std::env::var(LEAVE_AT_STEP_ENV).ok().and_then(|v| v.parse().ok());
    let mut shim = WorkerShim {
        codec: WireState::codec_for(cfg),
        n: ack.n,
        w: worker,
        d,
        plan: ShardPlan::new(d, cfg.comm.shards),
        install: ShardAssembly::default(),
        base_x: vec![0.0; d],
        base_acc: vec![0.0; d],
        collect_raw: false,
        step: 0,
        scratch: Vec::new(),
    };

    // Forward the cell's start-up Ready (or build-failure Err).
    let first = reply_rx
        .recv()
        .map_err(|_| Error::Protocol("worker cell exited before Ready".into()))?;
    let fatal = matches!(first, Reply::Err { .. });
    for f in shim.reply_to_frames(first)? {
        f.write_to(&mut stream)?;
    }
    if fatal {
        return Err(Error::Protocol("worker cell failed to start".into()));
    }

    let run = shim_loop(&mut stream, &mut shim, &cmd_tx, &reply_rx, exit_at, leave_at);
    drop(cmd_tx);
    let _ = cell.join();
    run
}

fn shim_loop(
    stream: &mut NetStream,
    shim: &mut WorkerShim,
    cmd_tx: &Sender<Cmd>,
    reply_rx: &Receiver<Reply>,
    exit_at: Option<u64>,
    leave_at: Option<u64>,
) -> Result<()> {
    loop {
        let frame = match Frame::read_from(stream)? {
            Some(f) => f,
            None => {
                return Err(Error::Protocol(
                    "leader closed the connection without Stop".into(),
                ))
            }
        };
        if matches!(frame.kind, FrameKind::SyncStep | FrameKind::LocalStep)
            && leave_at == Some(frame.step)
        {
            // Graceful departure: announce the leave in place of the
            // step reply, then exit cleanly — the leader bills a leave,
            // not a crash.
            Frame::control(FrameKind::Leave, shim.w as u32, frame.step).write_to(stream)?;
            return Ok(());
        }
        let is_stop = frame.kind == FrameKind::Stop;
        let cmd = match shim.frame_to_cmd(&frame, exit_at)? {
            Some(c) => c,
            // Partial shard install — await its remaining shard frames.
            None => continue,
        };
        if cmd_tx.send(cmd).is_err() {
            return Err(Error::Protocol("worker cell terminated unexpectedly".into()));
        }
        if is_stop {
            return Ok(());
        }
        let reply = reply_rx
            .recv()
            .map_err(|_| Error::Protocol("worker cell terminated unexpectedly".into()))?;
        let fatal = matches!(reply, Reply::Err { .. });
        for f in shim.reply_to_frames(reply)? {
            f.write_to(stream)?;
        }
        if fatal {
            return Err(Error::Protocol("worker cell failed".into()));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hello_ack_roundtrip() {
        let spec = WorkerSpec {
            worker: 2,
            algorithm: crate::config::Algorithm::LocalAdaAlter,
            epsilon: 1.0,
            b0: 1.0,
            init: Arc::new(vec![0.5, -1.25, 3.0]),
            allow_fused: true,
            collect_update_sq: false,
            bf16_state: true,
            crash_step: Some(7),
        };
        let ack = decode_hello_ack(&encode_hello_ack(4, &spec)).unwrap();
        assert_eq!(ack.n, 4);
        assert!(ack.allow_fused);
        assert!(!ack.collect_update_sq);
        assert!(ack.bf16_state);
        assert_eq!(ack.crash_step, Some(7));
        assert_eq!(ack.init, vec![0.5, -1.25, 3.0]);
        // No crash step encodes as 0.
        let spec2 = WorkerSpec { crash_step: None, ..spec };
        assert_eq!(decode_hello_ack(&encode_hello_ack(4, &spec2)).unwrap().crash_step, None);
        // Truncated payloads are clean errors.
        assert!(decode_hello_ack(&[0u8; 7]).is_err());
    }

    #[test]
    fn state_payload_splits() {
        let d = 3;
        let mut p = Vec::new();
        put_f32s(&mut p, &[1.0, 2.0, 3.0]);
        let (x, acc) = split_raw_state(&p, d).unwrap();
        assert_eq!(x, vec![1.0, 2.0, 3.0]);
        assert!(acc.is_none());
        put_f32s(&mut p, &[4.0, 5.0, 6.0]);
        let (_, acc) = split_raw_state(&p, d).unwrap();
        assert_eq!(acc.unwrap(), vec![4.0, 5.0, 6.0]);
        assert!(split_raw_state(&p[..5], d).is_err());
        let enc = vec![0u8; 10];
        assert!(split_enc_state(&enc, 10).unwrap().1.is_none());
        let enc2 = vec![0u8; 20];
        assert!(split_enc_state(&enc2, 10).unwrap().1.is_some());
        assert!(split_enc_state(&enc2[..15], 10).is_err());
    }

    #[test]
    fn counters_accumulate() {
        let c = NetCounters::new();
        c.add_accounted(10);
        c.add_total(38);
        c.add_accounted(5);
        assert_eq!(c.accounted(), 15);
        assert_eq!(c.total(), 38);
    }

    #[test]
    fn socket_kind_parses_transports() {
        assert_eq!(SocketKind::from_transport("tcp"), Some(SocketKind::Tcp));
        assert_eq!(SocketKind::from_transport("uds"), Some(SocketKind::Uds));
        assert_eq!(SocketKind::from_transport("channel"), None);
    }

    #[test]
    fn port_file_roundtrip_and_timeout() {
        let dir = std::env::temp_dir().join(format!("adaalter_portfile_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("port").to_string_lossy().into_owned();
        write_port_file(&path, "127.0.0.1:4321").unwrap();
        assert_eq!(read_port_file(&path, Duration::from_secs(1)).unwrap(), "127.0.0.1:4321");
        let missing = dir.join("absent").to_string_lossy().into_owned();
        let err = read_port_file(&missing, Duration::from_millis(30)).unwrap_err();
        assert!(err.to_string().contains("net.connect"), "{err}");
        // The error names the bounding field AND its configured value —
        // the operator sees which knob to turn without reading source.
        assert!(err.to_string().contains("net.connect_timeout_s = 0.03"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retry_backoff_is_capped_and_overflow_safe() {
        let base = Duration::from_millis(100);
        // Linear below the cap.
        assert_eq!(retry_backoff(base, 1), Duration::from_millis(100));
        assert_eq!(retry_backoff(base, 3), Duration::from_millis(300));
        // Capped once base × attempt crosses MAX_RETRY_BACKOFF.
        assert_eq!(retry_backoff(base, 1_000_000), MAX_RETRY_BACKOFF);
        // `Duration * u32` panics on overflow; the helper must not —
        // this exact pair overflows a u64 nanosecond product.
        assert_eq!(retry_backoff(Duration::from_secs(1u64 << 40), u32::MAX), MAX_RETRY_BACKOFF);
        // Zero base stays zero (no accidental cap promotion).
        assert_eq!(retry_backoff(Duration::ZERO, u32::MAX), Duration::ZERO);
    }

    #[test]
    fn connect_failure_reports_without_a_final_backoff_sleep() {
        // retries = 0 with a huge backoff: a post-final-attempt sleep
        // would stall this test for 10 s; the error must come back at
        // connection-refused speed.
        let mut cfg = ExperimentConfig::default();
        cfg.net.connect_retries = 0;
        cfg.net.retry_backoff_s = 10.0;
        let start = Instant::now();
        // Port 1 on loopback: reserved, nothing listens — immediate
        // ECONNREFUSED.
        let err = connect_with_retry(&cfg, SocketKind::Tcp, "127.0.0.1:1").unwrap_err();
        assert!(start.elapsed() < Duration::from_secs(5), "slept after the final attempt");
        let msg = err.to_string();
        assert!(msg.contains("net.connect_retries = 0"), "{msg}");
        assert!(msg.contains("after 1 attempts"), "{msg}");
    }

    #[test]
    fn shard_split_reassembles_to_the_dense_payload() {
        // Two 4-byte/elem sections over an uneven partition: the shard
        // payloads must cover the dense bytes exactly and reassemble to
        // them byte-for-byte, with the section interleave undone.
        let d = 10usize;
        let plan = ShardPlan::new(d, 4); // ranges 3 | 3 | 2 | 2
        let mut dense = Vec::new();
        for i in 0..2 * d {
            dense.extend_from_slice(&(i as u32).to_le_bytes());
        }
        let parts = split_state_payload(&dense, 4, &plan).unwrap();
        assert_eq!(parts.len(), 4);
        assert_eq!(parts.iter().map(Vec::len).sum::<usize>(), dense.len());
        // Shard 0 carries x[0..3] then acc[0..3].
        assert_eq!(parts[0].len(), 2 * 4 * 3);
        assert_eq!(&parts[0][..4], &0u32.to_le_bytes());
        assert_eq!(&parts[0][12..16], &(d as u32).to_le_bytes());
        let mut asm = ShardAssembly::default();
        for (s, p) in parts.iter().enumerate() {
            let out = asm.push(&plan, 4, s, p).unwrap();
            if s + 1 < parts.len() {
                assert!(out.is_none(), "completed early at shard {s}");
            } else {
                assert_eq!(out.unwrap(), dense, "reassembly not byte-identical");
            }
        }
        // The assembly reset itself: a second round works.
        for (s, p) in parts.iter().enumerate() {
            let out = asm.push(&plan, 4, s, p).unwrap();
            assert_eq!(out.is_some(), s + 1 == parts.len());
        }
        // Out-of-order shards are a protocol error (TCP FIFO makes them
        // impossible in a healthy run).
        let err = asm.push(&plan, 4, 1, &parts[1]).unwrap_err();
        assert!(err.to_string().contains("out of order"), "{err}");

        // Single-section payloads split too (x-only sync rounds).
        let parts = split_state_payload(&dense[..4 * d], 4, &plan).unwrap();
        assert_eq!(parts.iter().map(Vec::len).sum::<usize>(), 4 * d);
        // Junk lengths are clean errors.
        assert!(split_state_payload(&dense[..4 * d - 1], 4, &plan).is_err());
        assert!(split_state_payload(&[], 4, &plan).is_err());
    }

    #[test]
    fn shard_split_handles_more_shards_than_elements() {
        // k > d: tail shards are empty ranges — zero-length payload
        // frames that must still reassemble cleanly.
        let d = 3usize;
        let plan = ShardPlan::new(d, 5);
        let mut dense = Vec::new();
        for i in 0..d {
            dense.extend_from_slice(&(i as u32).to_le_bytes());
        }
        let parts = split_state_payload(&dense, 4, &plan).unwrap();
        assert_eq!(parts.len(), 5);
        assert_eq!(parts[3].len(), 0);
        assert_eq!(parts[4].len(), 0);
        let mut asm = ShardAssembly::default();
        let mut got = None;
        for (s, p) in parts.iter().enumerate() {
            got = asm.push(&plan, 4, s, p).unwrap();
            assert_eq!(got.is_some(), s + 1 == parts.len());
        }
        assert_eq!(got.unwrap(), dense);
    }
}
