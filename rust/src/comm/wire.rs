//! Binary wire format for the networked transport (DESIGN.md §4).
//!
//! Every message between a leader and a worker process is one
//! length-prefixed **frame**:
//!
//! ```text
//! offset  size  field
//!      0     4  magic        0x41444157 ("ADAW", little-endian on the wire)
//!      4     1  version      protocol version (1)
//!      5     1  kind         FrameKind discriminant
//!      6     1  codec        payload-codec tag (CODEC_RAW / _BF16 / _QSGD)
//!      7     1  flags        bit 0 = raw/observer payload (unbilled)
//!      8     4  worker       sender/addressee worker id
//!     12     8  step         iteration the frame belongs to
//!     20     4  payload_len  bytes that follow the header
//!     24     4  crc32        IEEE CRC-32 of the payload bytes
//!     28     …  payload
//! ```
//!
//! Payloads reuse the **existing codec bytes verbatim** as the wire
//! encoding: dense f32 little-endian, bf16 (`util::half`, 2 bytes/elem),
//! or QSGD (`comm::compress`: f32 norm + bit-packed signed levels) — so
//! the bytes a frame carries are exactly the bytes the in-process
//! compressed collective bills. Decoding is strict: bad magic/version,
//! unknown kinds, truncated or oversized frames and CRC mismatches all
//! come back as clean [`Error::Protocol`]s, never panics (property- and
//! fuzz-tested below).

use std::io::{Read, Write};

use crate::config::ExperimentConfig;
use crate::error::{Error, Result};
use crate::util::half;
use crate::util::rng::Rng;

use super::compress::{QsgdEncoded, QsgdQuantizer};

/// Frame magic ("ADAW" as a little-endian u32).
pub const MAGIC: u32 = 0x5741_4441;
/// Wire-protocol version; bumped on any incompatible frame change.
pub const PROTOCOL_VERSION: u8 = 1;
/// Frame header size in bytes.
pub const HEADER_LEN: usize = 28;
/// Hard cap on a single frame payload (64 MiB) — oversized lengths are
/// rejected before any allocation.
pub const MAX_PAYLOAD: u32 = 64 << 20;

/// Payload-codec tag: dense little-endian f32.
pub const CODEC_RAW: u8 = 0;
/// Payload-codec tag: bf16 (2 bytes/element).
pub const CODEC_BF16: u8 = 1;
/// Payload-codec tag: QSGD (f32 norm + bit-packed levels).
pub const CODEC_QSGD: u8 = 2;

/// Frame flag bit 0: raw/observer payload — exact f32, excluded from the
/// billed traffic accounting (checkpoint/eval/final-state collects).
pub const FLAG_RAW: u8 = 1;

/// Frame flag bits 1..7 carry the shard index of a shard-addressed
/// sync-round frame (`State` collects and `InstallState` installs when
/// `comm.shards > 1`; DESIGN.md §3). Shard 0 encodes as 0, so
/// single-shard frames are byte-identical to the pre-sharding wire
/// format.
pub const SHARD_FLAG_SHIFT: u32 = 1;

/// Encode a shard index into the frame's shard flag bits.
pub fn shard_flags(shard: usize) -> u8 {
    debug_assert!(shard < 128, "shard index does not fit the 7 shard flag bits");
    (shard as u8) << SHARD_FLAG_SHIFT
}

/// The shard index a frame's flags carry (0 for unsharded frames).
pub fn flags_shard(flags: u8) -> usize {
    (flags >> SHARD_FLAG_SHIFT) as usize
}

/// The frame vocabulary — every `Cmd`/`Reply` of the lockstep protocol
/// plus the connection handshake.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// Worker → leader: handshake (protocol version, id, config hash).
    Hello = 1,
    /// Leader → worker: handshake accept (cluster shape + worker spec).
    HelloAck = 2,
    /// Leader → worker: `Cmd::SyncStep` (payload: x, codec-encoded).
    SyncStep = 3,
    /// Leader → worker: `Cmd::LocalStep` (payload: f32 lr).
    LocalStep = 4,
    /// Leader → worker: `Cmd::CollectState` (flags bit 0 = raw collect).
    CollectState = 5,
    /// Leader → worker: `Cmd::InstallState` (payload: x [+ acc] sections).
    InstallState = 6,
    /// Leader → worker: `Cmd::Eval` (payload: optional raw f32 x).
    Eval = 7,
    /// Leader → worker: `Cmd::Stop` (empty payload).
    Stop = 8,
    /// Worker → leader: `Reply::Grad` (payload: f32 loss + encoded grad).
    Grad = 9,
    /// Worker → leader: `Reply::StepDone` (payload: f32 loss + f64 ‖Δx‖²).
    StepDone = 10,
    /// Worker → leader: `Reply::State` (payload: x [+ acc] sections).
    State = 11,
    /// Worker → leader: `Reply::Eval` (payload: eval metrics).
    EvalDone = 12,
    /// Worker → leader: `Reply::Ready` (empty payload).
    Ready = 13,
    /// Worker → leader: `Reply::Crashed` tombstone (step = crash step).
    Crashed = 14,
    /// Either direction: a fatal error message (payload: UTF-8).
    ErrMsg = 15,
    /// Worker → leader: late handshake of a relaunched worker process
    /// (same shape as [`FrameKind::Hello`]: protocol version in `step`,
    /// config fingerprint as payload). The leader parks the connection
    /// until its membership layer admits the worker at the next sync
    /// boundary — the `HelloAck` is the admission signal.
    Join = 16,
    /// Worker → leader: voluntary departure at `step` (empty payload).
    /// The peer closes its socket right after; the leader bills the
    /// departure as a leave, not a crash.
    Leave = 17,
}

impl FrameKind {
    /// Decode a kind discriminant; unknown values are a clean error.
    pub fn from_u8(v: u8) -> Result<FrameKind> {
        use FrameKind::*;
        Ok(match v {
            1 => Hello,
            2 => HelloAck,
            3 => SyncStep,
            4 => LocalStep,
            5 => CollectState,
            6 => InstallState,
            7 => Eval,
            8 => Stop,
            9 => Grad,
            10 => StepDone,
            11 => State,
            12 => EvalDone,
            13 => Ready,
            14 => Crashed,
            15 => ErrMsg,
            16 => Join,
            17 => Leave,
            other => {
                return Err(Error::Protocol(format!("unknown frame kind {other}")))
            }
        })
    }

    /// All kinds — the property tests sweep every one.
    pub const ALL: [FrameKind; 17] = [
        FrameKind::Hello,
        FrameKind::HelloAck,
        FrameKind::SyncStep,
        FrameKind::LocalStep,
        FrameKind::CollectState,
        FrameKind::InstallState,
        FrameKind::Eval,
        FrameKind::Stop,
        FrameKind::Grad,
        FrameKind::StepDone,
        FrameKind::State,
        FrameKind::EvalDone,
        FrameKind::Ready,
        FrameKind::Crashed,
        FrameKind::ErrMsg,
        FrameKind::Join,
        FrameKind::Leave,
    ];
}

/// One wire frame (header fields + owned payload bytes).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    /// What the frame carries.
    pub kind: FrameKind,
    /// Payload-codec tag ([`CODEC_RAW`] / [`CODEC_BF16`] / [`CODEC_QSGD`]).
    pub codec: u8,
    /// Frame flags ([`FLAG_RAW`]).
    pub flags: u8,
    /// Sender (worker→leader) or addressee (leader→worker) worker id.
    pub worker: u32,
    /// Iteration the frame belongs to (0 where not meaningful).
    pub step: u64,
    /// Codec-encoded payload bytes.
    pub payload: Vec<u8>,
}

impl Frame {
    /// A payload-less frame of `kind` for `worker`.
    pub fn control(kind: FrameKind, worker: u32, step: u64) -> Frame {
        Frame { kind, codec: CODEC_RAW, flags: 0, worker, step, payload: Vec::new() }
    }

    /// Total encoded size (header + payload).
    pub fn wire_len(&self) -> usize {
        HEADER_LEN + self.payload.len()
    }

    /// Serialize to bytes.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_len());
        self.encode_into(&mut out);
        out
    }

    /// Serialize, appending to `out` — the allocation-free form the
    /// coalescing writer threads use to stage several frames into one
    /// pooled buffer before a single vectored submission.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.reserve(self.wire_len());
        self.encode_header_into(out);
        out.extend_from_slice(&self.payload);
    }

    /// Serialize only the [`HEADER_LEN`]-byte header (which covers the
    /// payload via its length and CRC fields), appending to `out`. The
    /// zero-copy writer path stages headers contiguously and submits
    /// `[header][payload]` pairs with `write_vectored`, so payload bytes
    /// go from their staging buffer to the socket without being copied.
    pub fn encode_header_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.push(PROTOCOL_VERSION);
        out.push(self.kind as u8);
        out.push(self.codec);
        out.push(self.flags);
        out.extend_from_slice(&self.worker.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&crc32(&self.payload).to_le_bytes());
    }

    /// Decode one frame from the front of `buf`. Returns the frame and the
    /// number of bytes consumed. All malformed inputs are clean errors.
    pub fn decode(buf: &[u8]) -> Result<(Frame, usize)> {
        if buf.len() < HEADER_LEN {
            return Err(Error::Protocol(format!(
                "truncated frame header ({} of {HEADER_LEN} bytes)",
                buf.len()
            )));
        }
        let header: [u8; HEADER_LEN] = buf[..HEADER_LEN].try_into().expect("sized");
        let (kind, codec, flags, worker, step, len, crc) = parse_header(&header)?;
        let total = HEADER_LEN + len as usize;
        if buf.len() < total {
            return Err(Error::Protocol(format!(
                "truncated frame payload ({} of {len} bytes)",
                buf.len() - HEADER_LEN
            )));
        }
        let payload = buf[HEADER_LEN..total].to_vec();
        check_crc(&payload, crc)?;
        Ok((Frame { kind, codec, flags, worker, step, payload }, total))
    }

    /// Write the frame to a stream.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(&self.encode())?;
        Ok(())
    }

    /// Read one frame from a stream. `Ok(None)` on clean EOF at a frame
    /// boundary; mid-frame EOF and malformed headers are errors.
    pub fn read_from(r: &mut impl Read) -> Result<Option<Frame>> {
        let mut header = [0u8; HEADER_LEN];
        let mut got = 0;
        while got < HEADER_LEN {
            let n = r.read(&mut header[got..])?;
            if n == 0 {
                if got == 0 {
                    return Ok(None);
                }
                return Err(Error::Protocol(format!(
                    "connection closed mid-header ({got} of {HEADER_LEN} bytes)"
                )));
            }
            got += n;
        }
        let (kind, codec, flags, worker, step, len, crc) = parse_header(&header)?;
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload).map_err(|e| {
            Error::Protocol(format!("connection closed mid-payload ({len} bytes expected): {e}"))
        })?;
        check_crc(&payload, crc)?;
        Ok(Some(Frame { kind, codec, flags, worker, step, payload }))
    }
}

/// Hard cap on frames coalesced into one [`FrameBatch`] submission —
/// bounds the stack-allocated `IoSlice` table (2 slices per frame) and
/// matches the `comm.pipeline` validation ceiling.
pub const MAX_BATCH: usize = 16;

/// A coalesced batch of frames staged for one vectored socket
/// submission — the pipelined writer-thread path (`[comm] pipeline`).
///
/// [`FrameBatch::stage`] encodes each frame's 28-byte header into one
/// contiguous reusable buffer and keeps the frame (payload untouched);
/// [`FrameBatch::write_to`] submits all `[header][payload]` pairs with a
/// single `write_vectored` call (looping on partial writes), so payload
/// bytes travel from their staging buffers to the socket **without ever
/// being copied** — frame-at-a-time `Frame::encode` copied every payload
/// into a fresh allocation per frame. [`FrameBatch::recycle_into`]
/// returns the payload buffers to a [`BytePool`] afterwards, making the
/// whole encode → frame → queue → write cycle allocation-free at steady
/// state (pinned in `rust/tests/integration_alloc.rs`).
#[derive(Default)]
pub struct FrameBatch {
    headers: Vec<u8>,
    frames: Vec<Frame>,
}

impl FrameBatch {
    /// Empty batch (buffers grow to the working set, then stay).
    pub fn new() -> FrameBatch {
        FrameBatch::default()
    }

    /// Frames currently staged.
    pub fn len(&self) -> usize {
        self.frames.len()
    }

    /// Is nothing staged?
    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }

    /// Total wire bytes of the staged frames (headers + payloads).
    pub fn wire_len(&self) -> u64 {
        self.frames.iter().map(|f| f.wire_len() as u64).sum()
    }

    /// Stage `frame`: its header is encoded now, its payload referenced
    /// in place. Panics if the batch is already at [`MAX_BATCH`].
    pub fn stage(&mut self, frame: Frame) {
        assert!(self.frames.len() < MAX_BATCH, "FrameBatch over MAX_BATCH");
        frame.encode_header_into(&mut self.headers);
        self.frames.push(frame);
    }

    /// Write every staged frame with vectored submission, handling short
    /// writes. The staged frames stay in the batch (for byte accounting
    /// and payload recycling) until [`FrameBatch::recycle_into`] or
    /// [`FrameBatch::clear`].
    pub fn write_to(&mut self, w: &mut impl Write) -> std::io::Result<()> {
        let total = self.headers.len() + self.frames.iter().map(|f| f.payload.len()).sum::<usize>();
        let mut written = 0usize;
        while written < total {
            // Rebuild the slice table past what already went out — stack
            // storage only, no allocation on the resume path either.
            let mut slices = [std::io::IoSlice::new(&[]); 2 * MAX_BATCH];
            let mut ns = 0usize;
            let mut pos = 0usize;
            for (i, f) in self.frames.iter().enumerate() {
                let header = &self.headers[i * HEADER_LEN..(i + 1) * HEADER_LEN];
                for part in [header, f.payload.as_slice()] {
                    let end = pos + part.len();
                    if end > written && !part.is_empty() {
                        slices[ns] = std::io::IoSlice::new(&part[written.saturating_sub(pos)..]);
                        ns += 1;
                    }
                    pos = end;
                }
            }
            let n = w.write_vectored(&slices[..ns])?;
            if n == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "socket accepted 0 bytes of a staged frame batch",
                ));
            }
            written += n;
        }
        Ok(())
    }

    /// Drop the staged frames, returning their payload allocations to
    /// `pool` for the next round's encodes.
    pub fn recycle_into(&mut self, pool: &mut crate::util::pool::BytePool) {
        self.headers.clear();
        for f in self.frames.drain(..) {
            if f.payload.capacity() > 0 {
                pool.put(f.payload);
            }
        }
    }

    /// Drop the staged frames without recycling.
    pub fn clear(&mut self) {
        self.headers.clear();
        self.frames.clear();
    }
}

#[allow(clippy::type_complexity)]
fn parse_header(h: &[u8; HEADER_LEN]) -> Result<(FrameKind, u8, u8, u32, u64, u32, u32)> {
    let magic = u32::from_le_bytes(h[0..4].try_into().expect("sized"));
    if magic != MAGIC {
        return Err(Error::Protocol(format!("bad frame magic {magic:#010x}")));
    }
    if h[4] != PROTOCOL_VERSION {
        return Err(Error::Protocol(format!(
            "wire protocol version mismatch: peer speaks v{}, this build v{PROTOCOL_VERSION}",
            h[4]
        )));
    }
    let kind = FrameKind::from_u8(h[5])?;
    let len = u32::from_le_bytes(h[20..24].try_into().expect("sized"));
    if len > MAX_PAYLOAD {
        return Err(Error::Protocol(format!(
            "frame payload length {len} exceeds the {MAX_PAYLOAD}-byte cap"
        )));
    }
    let worker = u32::from_le_bytes(h[8..12].try_into().expect("sized"));
    let step = u64::from_le_bytes(h[12..20].try_into().expect("sized"));
    let crc = u32::from_le_bytes(h[24..28].try_into().expect("sized"));
    Ok((kind, h[6], h[7], worker, step, len, crc))
}

fn check_crc(payload: &[u8], expect: u32) -> Result<()> {
    let got = crc32(payload);
    if got != expect {
        return Err(Error::Protocol(format!(
            "frame CRC mismatch (computed {got:#010x}, header says {expect:#010x})"
        )));
    }
    Ok(())
}

/// IEEE CRC-32 lookup table (polynomial 0xEDB88320), built at compile time
/// — the image carries no crc crate.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// IEEE CRC-32 of `data`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// The per-encode RNG of the QSGD wire codec: derived fresh from
/// `(seed, stream, use-index)` so a worker process and the leader derive
/// **identical** stochastic-rounding draws for any stream without sharing
/// RNG state — the keying discipline of DESIGN.md §2, extended to codec
/// streams. The in-process compressed collective uses the same derivation,
/// which is exactly what makes the cross-process runs bitwise-identical.
pub fn qsgd_stream_rng(seed: u64, stream: u64, use_idx: u64) -> Rng {
    Rng::derive(seed, &[0xC0DE, stream, use_idx])
}

/// A payload codec: turns f32 vectors into the wire bytes of one of the
/// existing codecs and back. Stateful only for QSGD (per-stream use
/// counters + scratch).
pub enum PayloadCodec {
    /// Dense little-endian f32 (4 bytes/element).
    F32,
    /// bf16 (2 bytes/element, round-to-nearest-even).
    Bf16,
    /// QSGD stochastic quantization (f32 norm + bit-packed levels).
    Qsgd {
        /// The quantizer (levels s).
        q: QsgdQuantizer,
        /// Experiment seed the per-encode RNGs derive from.
        seed: u64,
        /// Per-stream encode counters (the RNG use index).
        uses: Vec<u64>,
        /// Encode scratch.
        enc: QsgdEncoded,
    },
}

impl PayloadCodec {
    /// QSGD codec with `s` levels keyed by the experiment seed.
    pub fn qsgd(s: u8, seed: u64) -> PayloadCodec {
        PayloadCodec::Qsgd {
            q: QsgdQuantizer::new(s),
            seed,
            uses: Vec::new(),
            enc: QsgdEncoded { norm: 0.0, levels: Vec::new(), s },
        }
    }

    /// The frame codec tag for this payload codec.
    pub fn tag(&self) -> u8 {
        match self {
            PayloadCodec::F32 => CODEC_RAW,
            PayloadCodec::Bf16 => CODEC_BF16,
            PayloadCodec::Qsgd { .. } => CODEC_QSGD,
        }
    }

    /// Is this the identity (dense f32) codec?
    pub fn is_f32(&self) -> bool {
        matches!(self, PayloadCodec::F32)
    }

    /// Exact encoded size of a d-element vector — deterministic, so both
    /// ends can bill traffic without materialising the bytes.
    pub fn enc_len(&self, d: usize) -> usize {
        match self {
            PayloadCodec::F32 => 4 * d,
            PayloadCodec::Bf16 => half::wire_bytes(d) as usize,
            PayloadCodec::Qsgd { q, .. } => q.wire_bytes(d) as usize,
        }
    }

    /// Encode `v` on codec stream `stream`, appending the wire bytes to
    /// `out`. QSGD burns one `(stream, use)` RNG per call.
    pub fn encode_vec(&mut self, stream: usize, v: &[f32], out: &mut Vec<u8>) {
        match self {
            PayloadCodec::F32 => {
                out.reserve(4 * v.len());
                for &x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            PayloadCodec::Bf16 => {
                out.reserve(2 * v.len());
                for &x in v {
                    out.extend_from_slice(&half::bf16_from_f32(x).to_le_bytes());
                }
            }
            PayloadCodec::Qsgd { q, seed, uses, enc } => {
                if uses.len() <= stream {
                    uses.resize(stream + 1, 0);
                }
                let mut rng = qsgd_stream_rng(*seed, stream as u64, uses[stream]);
                uses[stream] += 1;
                q.encode_to(v, &mut rng, enc);
                out.extend_from_slice(&enc.norm.to_le_bytes());
                pack_levels(&enc.levels, enc.s, out);
            }
        }
    }

    /// Decode `bytes` (an [`encode_vec`](Self::encode_vec) payload of a
    /// d = `out.len()` vector) into `out`. Length mismatches are clean
    /// errors. Decoding is deterministic — no RNG — so either end can
    /// decode any stream.
    pub fn decode_vec(&mut self, bytes: &[u8], out: &mut [f32]) -> Result<()> {
        let d = out.len();
        let want = self.enc_len(d);
        if bytes.len() != want {
            return Err(Error::Protocol(format!(
                "payload length {} != {want} expected for a {d}-element {} vector",
                bytes.len(),
                match self.tag() {
                    CODEC_RAW => "f32",
                    CODEC_BF16 => "bf16",
                    _ => "qsgd",
                }
            )));
        }
        match self {
            PayloadCodec::F32 => {
                for (i, o) in out.iter_mut().enumerate() {
                    *o = f32::from_le_bytes(bytes[4 * i..4 * i + 4].try_into().expect("sized"));
                }
            }
            PayloadCodec::Bf16 => {
                for (i, o) in out.iter_mut().enumerate() {
                    let bits =
                        u16::from_le_bytes(bytes[2 * i..2 * i + 2].try_into().expect("sized"));
                    *o = half::f32_from_bf16(bits);
                }
            }
            PayloadCodec::Qsgd { q, enc, .. } => {
                enc.norm = f32::from_le_bytes(bytes[0..4].try_into().expect("sized"));
                unpack_levels(&bytes[4..], enc.s, d, &mut enc.levels)?;
                q.decode(enc, out);
            }
        }
        Ok(())
    }
}

/// Bits per packed QSGD level for `s` quantization levels (2s+1 symbols).
fn level_bits(s: u8) -> u32 {
    64 - (2 * s as u64).leading_zeros()
}

/// Bit-pack signed levels in `[-s, s]` as unsigned `level + s`, LSB-first.
fn pack_levels(levels: &[i8], s: u8, out: &mut Vec<u8>) {
    let bits = level_bits(s);
    let mut acc: u64 = 0;
    let mut nbits: u32 = 0;
    for &l in levels {
        let u = (l as i16 + s as i16) as u64;
        acc |= u << nbits;
        nbits += bits;
        while nbits >= 8 {
            out.push((acc & 0xFF) as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out.push((acc & 0xFF) as u8);
    }
}

/// Inverse of [`pack_levels`]; out-of-range symbols are clean errors.
fn unpack_levels(bytes: &[u8], s: u8, d: usize, out: &mut Vec<i8>) -> Result<()> {
    let bits = level_bits(s);
    out.clear();
    out.reserve(d);
    let mask: u64 = (1u64 << bits) - 1;
    let mut acc: u64 = 0;
    let mut nbits: u32 = 0;
    let mut it = bytes.iter();
    for _ in 0..d {
        while nbits < bits {
            let b = it.next().ok_or_else(|| {
                Error::Protocol("qsgd payload too short for its level count".into())
            })?;
            acc |= (*b as u64) << nbits;
            nbits += 8;
        }
        let u = acc & mask;
        acc >>= bits;
        nbits -= bits;
        if u > 2 * s as u64 {
            return Err(Error::Protocol(format!(
                "qsgd level symbol {u} out of range for s = {s}"
            )));
        }
        out.push((u as i16 - s as i16) as i8);
    }
    Ok(())
}

/// FNV-1a hash of the semantically-relevant config surface — the
/// handshake's config-hash check. Covers everything that shapes the
/// training trajectory ([train]/[optim]/[data]/[comm]/[sync]/[faults]/
/// [precision]); excludes output paths, `[net]` addressing, `[exec]` and
/// `comm.pipeline` (pure wall-clock knobs — pipelined scheduling is
/// bitwise-identical by construction), so leader and workers may differ
/// in those.
pub fn config_fingerprint(cfg: &ExperimentConfig) -> u64 {
    let t = &cfg.train;
    let o = &cfg.optim;
    let canon = format!(
        "train:{preset}|{w}|{h}|{steps}|{spe}|{ee}|{le}|{seed}|{be:?}|{dim}|{ce}|{fused};\
         optim:{algo}|{eta}|{eps}|{b0}|{wu}|{mom};\
         data:{zs}|{mk}|{ni}|{eb};\
         comm:{tr}|{cmp}|{ql}|{tk}|{shards};\
         sync:{sp}|{hm}|{gf}|{ge}|{dt}|{tcf};\
         faults:{sw}|{sf}|{stp}|{sts}|{cw}|{cs}|{q}|{to}|{ds}\
         |{rj}|{spw}|{sps}|{asc}|{asp}|{ass}|{asd};\
         precision:{pw}|{ps}",
        preset = t.preset,
        w = t.workers,
        h = t.sync_period,
        steps = t.steps,
        spe = t.steps_per_epoch,
        ee = t.eval_every,
        le = t.log_every,
        seed = t.seed,
        be = t.backend,
        dim = t.rust_math_dim,
        ce = t.checkpoint_every,
        fused = t.fused,
        algo = o.algorithm,
        eta = o.eta,
        eps = o.epsilon,
        b0 = o.b0,
        wu = o.warmup_steps,
        mom = o.momentum,
        zs = cfg.data.zipf_s,
        mk = cfg.data.markov,
        ni = cfg.data.noniid,
        eb = cfg.data.eval_batches,
        tr = cfg.comm.transport,
        cmp = cfg.comm.compression,
        ql = cfg.comm.qsgd_levels,
        tk = cfg.comm.topk_keep,
        shards = cfg.comm.shards,
        sp = cfg.sync.policy,
        hm = cfg.sync.h_max,
        gf = cfg.sync.grow_factor,
        ge = cfg.sync.grow_every,
        dt = cfg.sync.drift_threshold,
        tcf = cfg.sync.target_comm_fraction,
        sw = cfg.faults.slow_workers,
        sf = cfg.faults.slow_factor,
        stp = cfg.faults.stall_prob,
        sts = cfg.faults.stall_s,
        cw = cfg.faults.crash_worker,
        cs = cfg.faults.crash_step,
        q = cfg.faults.quorum,
        to = cfg.faults.timeout_s,
        ds = cfg.faults.drop_slowest,
        rj = cfg.faults.rejoin_step,
        spw = cfg.faults.spawn_workers,
        sps = cfg.faults.spawn_step,
        asc = cfg.faults.autoscale,
        asp = cfg.faults.autoscale_patience,
        ass = cfg.faults.autoscale_straggler_s,
        asd = cfg.faults.autoscale_drift,
        pw = cfg.precision.wire,
        ps = cfg.precision.state,
    );
    canon.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x0100_0000_01b3)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self};

    fn arb_frame(g: &mut crate::util::prop::Gen, max_payload: usize) -> Frame {
        let kind = *g.choose(&FrameKind::ALL);
        let len = g.usize_in(0..max_payload + 1);
        let payload: Vec<u8> = (0..len).map(|_| (g.rng().next_u64() & 0xFF) as u8).collect();
        Frame {
            kind,
            codec: *g.choose(&[CODEC_RAW, CODEC_BF16, CODEC_QSGD]),
            flags: *g.choose(&[0u8, FLAG_RAW]),
            worker: g.u64_in(0..u32::MAX as u64) as u32,
            step: g.u64_in(0..u64::MAX - 1),
            payload,
        }
    }

    #[test]
    fn frame_roundtrip_every_kind_and_size() {
        prop::check("frame encode∘decode identity", 300, |g| {
            let f = arb_frame(g, 4096);
            let bytes = f.encode();
            let (back, used) = Frame::decode(&bytes).map_err(|e| e.to_string())?;
            prop::assert_that(used == bytes.len(), "consumed length")?;
            prop::assert_that(back == f, "frame mismatch after roundtrip")
        });
    }

    #[test]
    fn frame_roundtrip_zero_and_max_payload() {
        for len in [0usize, MAX_PAYLOAD as usize / 1024] {
            let f = Frame {
                kind: FrameKind::State,
                codec: CODEC_QSGD,
                flags: FLAG_RAW,
                worker: 7,
                step: 42,
                payload: vec![0xAB; len],
            };
            let (back, used) = Frame::decode(&f.encode()).unwrap();
            assert_eq!(used, HEADER_LEN + len);
            assert_eq!(back, f);
        }
    }

    #[test]
    fn crc_rejects_single_bit_flips() {
        prop::check("crc catches 1-bit payload flips", 200, |g| {
            let mut f = arb_frame(g, 512);
            if f.payload.is_empty() {
                f.payload.push(0x55);
            }
            let mut bytes = f.encode();
            let bit = g.usize_in(0..f.payload.len() * 8);
            bytes[HEADER_LEN + bit / 8] ^= 1 << (bit % 8);
            match Frame::decode(&bytes) {
                Err(e) => prop::assert_that(
                    e.to_string().contains("CRC"),
                    format!("wrong error for flipped bit: {e}"),
                ),
                Ok(_) => Err("bit flip went undetected".into()),
            }
        });
    }

    #[test]
    fn malformed_frames_are_clean_errors() {
        let good = Frame::control(FrameKind::Ready, 3, 9).encode();
        // Truncations at every prefix length: error, never panic.
        for cut in 0..good.len() {
            assert!(Frame::decode(&good[..cut]).is_err(), "cut {cut}");
        }
        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        let err = Frame::decode(&bad).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        // Bad version.
        let mut bad = good.clone();
        bad[4] = PROTOCOL_VERSION + 9;
        let err = Frame::decode(&bad).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        // Unknown kind.
        let mut bad = good.clone();
        bad[5] = 0xEE;
        let err = Frame::decode(&bad).unwrap_err().to_string();
        assert!(err.contains("kind"), "{err}");
        // Oversized payload length: rejected before allocation.
        let mut bad = good;
        bad[20..24].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        let err = Frame::decode(&bad).unwrap_err().to_string();
        assert!(err.contains("cap"), "{err}");
    }

    #[test]
    fn decoder_never_panics_on_random_bytes() {
        // Seeded corpus-style fuzz loop: random byte strings, plus mutated
        // valid frames (the interesting corpus), must never panic.
        prop::check("decoder total on arbitrary bytes", 500, |g| {
            let bytes: Vec<u8> = if g.bool() {
                let n = g.usize_in(0..256);
                (0..n).map(|_| (g.rng().next_u64() & 0xFF) as u8).collect()
            } else {
                let mut b = arb_frame(g, 128).encode();
                for _ in 0..g.usize_in(1..8) {
                    let i = g.usize_in(0..b.len());
                    b[i] = (g.rng().next_u64() & 0xFF) as u8;
                }
                b
            };
            let _ = Frame::decode(&bytes); // any Result is fine; panics fail
            Ok(())
        });
    }

    #[test]
    fn payload_codecs_roundtrip() {
        prop::check("f32/bf16 payload codec identity", 100, |g| {
            let v = g.vec_normal(1..200, 2.0);
            for mut codec in [PayloadCodec::F32, PayloadCodec::Bf16] {
                let mut bytes = Vec::new();
                codec.encode_vec(0, &v, &mut bytes);
                prop::assert_that(bytes.len() == codec.enc_len(v.len()), "enc_len")?;
                let mut out = vec![0.0f32; v.len()];
                codec.decode_vec(&bytes, &mut out).map_err(|e| e.to_string())?;
                let want: Vec<f32> = if codec.is_f32() {
                    v.clone()
                } else {
                    v.iter().map(|&x| half::round_f32(x)).collect()
                };
                prop::assert_that(
                    out.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "codec roundtrip not bitwise",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn qsgd_codec_matches_quantizer_bitwise() {
        // The wire bytes must reproduce QsgdQuantizer's encode→decode
        // exactly, including the (stream, use)-derived stochastic draws.
        prop::check("qsgd wire == quantizer roundtrip", 60, |g| {
            let s = *g.choose(&[1u8, 3, 15, 127]);
            let seed = g.u64_in(0..u64::MAX - 1);
            let v = g.vec_normal(1..150, 3.0);
            let stream = g.usize_in(0..17);
            let mut codec = PayloadCodec::qsgd(s, seed);
            let mut bytes = Vec::new();
            codec.encode_vec(stream, &v, &mut bytes);
            prop::assert_that(bytes.len() == codec.enc_len(v.len()), "enc_len")?;
            let mut out = vec![0.0f32; v.len()];
            codec.decode_vec(&bytes, &mut out).map_err(|e| e.to_string())?;
            // Reference: the quantizer with the same derived RNG (use 0).
            let q = QsgdQuantizer::new(s);
            let mut rng = qsgd_stream_rng(seed, stream as u64, 0);
            let enc = q.encode(&v, &mut rng);
            let mut want = vec![0.0f32; v.len()];
            q.decode(&enc, &mut want);
            prop::assert_that(
                out.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "qsgd wire roundtrip not bitwise",
            )?;
            // A second encode on the same stream uses the next RNG.
            let mut bytes2 = Vec::new();
            codec.encode_vec(stream, &v, &mut bytes2);
            let mut rng1 = qsgd_stream_rng(seed, stream as u64, 1);
            let enc1 = q.encode(&v, &mut rng1);
            let mut want1 = vec![0.0f32; v.len()];
            q.decode(&enc1, &mut want1);
            let mut out1 = vec![0.0f32; v.len()];
            PayloadCodec::qsgd(s, seed) // fresh decoder: stateless decode
                .decode_vec(&bytes2, &mut out1)
                .map_err(|e| e.to_string())?;
            prop::assert_that(
                out1.iter().zip(&want1).all(|(a, b)| a.to_bits() == b.to_bits()),
                "qsgd use-counter keying diverged",
            )
        });
    }

    #[test]
    fn qsgd_payload_length_errors_are_clean() {
        let mut codec = PayloadCodec::qsgd(15, 7);
        let v = vec![1.0f32; 33];
        let mut bytes = Vec::new();
        codec.encode_vec(0, &v, &mut bytes);
        let mut out = vec![0.0f32; 33];
        // Wrong length for d.
        assert!(codec.decode_vec(&bytes[..bytes.len() - 1], &mut out).is_err());
        // Out-of-range symbol: force every packed bit on.
        let mut evil = bytes.clone();
        for b in evil.iter_mut().skip(4) {
            *b = 0xFF;
        }
        let err = codec.decode_vec(&evil, &mut out).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn crc32_known_vector() {
        // The IEEE check value: CRC32("123456789") = 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn fingerprint_tracks_semantic_fields_only() {
        let a = ExperimentConfig::default();
        let mut b = ExperimentConfig::default();
        assert_eq!(config_fingerprint(&a), config_fingerprint(&b));
        b.out_dir = "elsewhere".into();
        b.exec.threads = 3;
        b.net.latency_us = 1.0;
        // comm.pipeline is pure leader-side scheduling (bitwise-identical
        // runs by construction), so like [exec] it must not enter the
        // handshake fingerprint — a pipelined leader accepts workers that
        // never heard of the knob.
        b.comm.pipeline = 4;
        assert_eq!(config_fingerprint(&a), config_fingerprint(&b), "non-semantic");
        b.train.seed += 1;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&b), "semantic");
        // The shard count shapes the data plane: leader and workers must
        // agree on it, so it is part of the handshake fingerprint.
        let mut c = ExperimentConfig::default();
        c.comm.shards = 4;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&c), "shards");
        // Elastic-membership keys shape who participates when — leader
        // and (re)joining workers must agree on the schedule.
        let mut d = ExperimentConfig::default();
        d.faults.rejoin_step = 9;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&d), "rejoin");
        let mut e = ExperimentConfig::default();
        e.faults.autoscale = true;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&e), "autoscale");
    }

    #[test]
    fn shard_flags_roundtrip_and_preserve_raw_bit() {
        // Shard 0 encodes as no flags at all — the k = 1 wire format is
        // byte-identical to the pre-sharding one.
        assert_eq!(shard_flags(0), 0);
        for s in [0usize, 1, 3, 63] {
            let f = shard_flags(s);
            assert_eq!(flags_shard(f), s);
            // The raw bit composes orthogonally.
            assert_eq!(flags_shard(f | FLAG_RAW), s);
            assert_eq!((f | FLAG_RAW) & FLAG_RAW, FLAG_RAW);
        }
    }

    /// A writer that accepts at most `max` bytes per call — exercises
    /// the batch writer's partial-write resume path, including splits
    /// inside headers and inside payloads.
    struct Trickle {
        out: Vec<u8>,
        max: usize,
    }

    impl std::io::Write for Trickle {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            let n = buf.len().min(self.max);
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn frame_batch_bytes_equal_sequential_encodes() {
        prop::check("batched vectored write ≡ frame-at-a-time encode", 60, |g| {
            let k = 1 + g.usize_in(0..MAX_BATCH);
            let frames: Vec<Frame> = (0..k).map(|_| arb_frame(g, 256)).collect();
            let mut expect = Vec::new();
            for f in &frames {
                expect.extend_from_slice(&f.encode());
            }
            let mut batch = FrameBatch::new();
            for f in &frames {
                batch.stage(f.clone());
            }
            prop::assert_that(batch.len() == k, "len tracks staged frames")?;
            prop::assert_that(batch.wire_len() == expect.len() as u64, "wire_len")?;
            let mut sink = Vec::new();
            batch.write_to(&mut sink).map_err(|e| e.to_string())?;
            prop::assert_that(sink == expect, "byte-identical wire image")?;
            // The staged bytes decode back to the original frames.
            let mut rest: &[u8] = &sink;
            for f in &frames {
                let (back, used) = Frame::decode(rest).map_err(|e| e.to_string())?;
                prop::assert_that(&back == f, "decoded frame mismatch")?;
                rest = &rest[used..];
            }
            prop::assert_that(rest.is_empty(), "no trailing bytes")
        });
    }

    #[test]
    fn frame_batch_survives_short_writes() {
        prop::check("batched write resumes across short writes", 40, |g| {
            let k = 1 + g.usize_in(0..MAX_BATCH);
            let frames: Vec<Frame> = (0..k).map(|_| arb_frame(g, 128)).collect();
            let mut expect = Vec::new();
            for f in &frames {
                expect.extend_from_slice(&f.encode());
            }
            let mut batch = FrameBatch::new();
            for f in &frames {
                batch.stage(f.clone());
            }
            // max = 1..17 bytes per call splits inside headers and
            // payloads; the default `write_vectored` also only consumes
            // the first non-empty slice per call, exercising the table
            // rebuild.
            let mut w = Trickle { out: Vec::new(), max: 1 + g.usize_in(0..17) };
            batch.write_to(&mut w).map_err(|e| e.to_string())?;
            prop::assert_that(w.out == expect, "byte-identical after short writes")
        });
    }

    #[test]
    fn frame_batch_recycles_payload_buffers() {
        let mut pool = crate::util::pool::BytePool::new();
        let mut batch = FrameBatch::new();
        batch.stage(Frame {
            kind: FrameKind::SyncStep,
            codec: CODEC_RAW,
            flags: 0,
            worker: 0,
            step: 1,
            payload: vec![1, 2, 3],
        });
        // Payload-less control frames have no allocation to recycle.
        batch.stage(Frame::control(FrameKind::Stop, 1, 1));
        let mut sink = Vec::new();
        batch.write_to(&mut sink).unwrap();
        batch.recycle_into(&mut pool);
        assert!(batch.is_empty());
        assert_eq!(pool.parked(), 1, "one owned payload returned");
        assert!(pool.take().is_empty(), "recycled buffer comes back cleared");
        // A cleared batch is reusable: staging again starts fresh.
        batch.stage(Frame::control(FrameKind::Ready, 2, 2));
        assert_eq!(batch.len(), 1);
        assert_eq!(batch.wire_len(), HEADER_LEN as u64);
    }
}
