//! Gradient-compression baselines — the *other* family of communication
//! reduction the paper positions itself against (§1: "quantization (Seide
//! et al., QSGD, signSGD, …) and sparsification (Aji & Heafield, Stich
//! et al., …)"). Implemented so the benches can compare bytes-on-the-wire
//! and convergence against local SGD / local AdaAlter on equal footing.
//!
//! * [`QsgdQuantizer`] — QSGD (Alistarh et al. 2016): stochastic uniform
//!   quantization to `s` levels per |coordinate| relative to the vector's
//!   L2 norm; unbiased (`E[decode(encode(g))] = g`).
//! * [`TopKSparsifier`] — magnitude top-k with local error feedback (Stich
//!   et al. 2018's memory): the dropped mass is carried to the next round,
//!   which is what makes sparsified SGD converge.
//!
//! Both report their exact wire size so the comm accounting is honest.
//!
//! A third wire format lives next door: `precision.wire = "bf16"` rounds
//! every payload through bf16 ([`crate::util::half`], round-to-nearest-
//! even) at exactly 2 bytes/element — half the dense f32 wire, with a
//! fixed ~0.4% relative error instead of QSGD's norm-scaled noise. It
//! plugs into the same compressed-collective machinery (delta coding,
//! exact byte accounting) as a stateless codec, so the three families are
//! directly comparable in `benches/comm_reduction.rs` (DESIGN.md §8).

use crate::util::rng::Rng;

/// An encoded QSGD gradient: norm + per-coordinate (sign, level).
#[derive(Clone, Debug)]
pub struct QsgdEncoded {
    /// L2 norm of the encoded vector (the shared scale factor).
    pub norm: f32,
    /// Quantization levels in `[-s, s]`, one per coordinate.
    pub levels: Vec<i8>,
    /// Quantization level count s the message was encoded with.
    pub s: u8,
}

/// QSGD stochastic quantizer with `s` levels (s ≤ 127).
pub struct QsgdQuantizer {
    s: u8,
}

impl QsgdQuantizer {
    /// `s` quantization levels. `s` must be in `1..=127`: levels are i8
    /// codes, and an `s` above 127 would wrap negative in the clamp and
    /// silently flip every gradient's sign.
    pub fn new(s: u8) -> Self {
        assert!((1..=127).contains(&s), "QSGD levels must be in 1..=127 (i8 code space)");
        QsgdQuantizer { s }
    }

    /// Encode: `levels[i] = sign(g_i) · ξ(|g_i|·s/‖g‖)` where ξ rounds up
    /// with probability equal to the fractional part (unbiasedness).
    ///
    /// Allocating convenience wrapper over [`QsgdQuantizer::encode_to`]
    /// (which hot paths call with a reused scratch message instead).
    pub fn encode(&self, g: &[f32], rng: &mut Rng) -> QsgdEncoded {
        let mut enc = QsgdEncoded { norm: 0.0, levels: Vec::new(), s: self.s };
        self.encode_to(g, rng, &mut enc);
        enc
    }

    /// [`QsgdQuantizer::encode`] into a caller-owned message, reusing its
    /// `levels` buffer — the zero-allocation hot path (DESIGN.md §7).
    ///
    /// Edge cases are handled explicitly so `decode(encode(g))` is finite
    /// for every all-finite input and degrades gracefully otherwise:
    /// non-finite coordinates encode to level 0 (dropped), the norm is
    /// computed over finite coordinates only and saturates at `f32::MAX`,
    /// and levels are clamped to `s` (fp roundoff can push `|g_i|/‖g‖`
    /// past 1, and `|decoded_i| ≤ ‖g‖` only holds under the clamp).
    pub fn encode_to(&self, g: &[f32], rng: &mut Rng, enc: &mut QsgdEncoded) {
        let norm64 = g
            .iter()
            .filter(|v| v.is_finite())
            .map(|&v| (v as f64) * (v as f64))
            .sum::<f64>()
            .sqrt();
        let norm = (norm64 as f32).min(f32::MAX);
        enc.s = self.s;
        enc.norm = norm;
        enc.levels.clear();
        enc.levels.resize(g.len(), 0);
        if norm > 0.0 {
            let s = self.s as f32;
            for (l, &v) in enc.levels.iter_mut().zip(g) {
                if !v.is_finite() {
                    continue;
                }
                let u = (v.abs() / norm * s).min(s);
                let floor = u.floor();
                let up = rng.f32() < u - floor;
                let level = (floor as i8 + up as i8).min(self.s as i8);
                *l = if v.is_sign_negative() { -level } else { level };
            }
        }
    }

    /// Decode back to a dense vector. The product is taken in f64 and
    /// clamped: with a saturated norm (`f32::MAX`) and a max-level
    /// coordinate, `level · fl32(norm/s)` rounds up to +inf in f32, which
    /// would break the finite-roundtrip guarantee.
    pub fn decode(&self, enc: &QsgdEncoded, out: &mut [f32]) {
        assert_eq!(enc.levels.len(), out.len());
        let scale = enc.norm as f64 / enc.s as f64;
        let max = f32::MAX as f64;
        for (o, &l) in out.iter_mut().zip(&enc.levels) {
            *o = (l as f64 * scale).clamp(-max, max) as f32;
        }
    }

    /// The configured level count `s`.
    pub fn levels(&self) -> u8 {
        self.s
    }

    /// Wire bytes for one encoded gradient: 4 (norm) + ceil(d·b/8) with
    /// b = bits for `2s+1` symbols (entropy-code-free upper bound).
    pub fn wire_bytes(&self, d: usize) -> u64 {
        let symbols = 2 * self.s as u64 + 1;
        let bits = 64 - (symbols - 1).leading_zeros() as u64;
        4 + (d as u64 * bits).div_ceil(8)
    }
}

/// Top-k sparsifier with error feedback ("memory").
pub struct TopKSparsifier {
    /// Fraction of coordinates kept per round.
    pub keep: f64,
    /// Error-feedback residual (dropped mass carried forward).
    residual: Vec<f32>,
    /// Reused partial-select index scratch (no per-encode allocation).
    order: Vec<u32>,
}

/// A sparse (index, value) gradient message.
#[derive(Clone, Debug)]
pub struct SparseGrad {
    /// Dense dimension the message reconstructs into.
    pub d: usize,
    /// Kept coordinate indices.
    pub idx: Vec<u32>,
    /// Kept coordinate values (parallel to `idx`).
    pub val: Vec<f32>,
}

impl SparseGrad {
    /// Dense reconstruction (zeros elsewhere).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.d];
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] = v;
        }
        out
    }

    /// Wire bytes: 4 per index + 4 per value.
    pub fn wire_bytes(&self) -> u64 {
        (self.idx.len() * 8) as u64
    }
}

impl TopKSparsifier {
    /// Keep the top `keep` fraction (e.g. 0.01) of coordinates by |value|.
    pub fn new(d: usize, keep: f64) -> Self {
        assert!((0.0..=1.0).contains(&keep) && keep > 0.0);
        TopKSparsifier { keep, residual: vec![0.0; d], order: Vec::new() }
    }

    /// Encode `g + residual`, keep top-k, stash the rest back as residual.
    /// Allocating convenience wrapper over
    /// [`TopKSparsifier::encode_into`].
    pub fn encode(&mut self, g: &[f32]) -> SparseGrad {
        let mut out = SparseGrad { d: self.residual.len(), idx: Vec::new(), val: Vec::new() };
        self.encode_into(g, &mut out);
        out
    }

    /// [`TopKSparsifier::encode`] into a caller-owned message, reusing its
    /// `idx`/`val` buffers and this sparsifier's select scratch — the
    /// zero-allocation hot path (DESIGN.md §7).
    pub fn encode_into(&mut self, g: &[f32], out: &mut SparseGrad) {
        let d = self.residual.len();
        assert_eq!(g.len(), d);
        let k = ((d as f64 * self.keep).ceil() as usize).clamp(1, d);
        let residual = &mut self.residual;
        // accumulate into residual: r += g
        for (r, &v) in residual.iter_mut().zip(g) {
            *r += v;
        }
        // Partial select: indices of the k largest |residual|.
        let order = &mut self.order;
        order.clear();
        order.extend(0..d as u32);
        order.select_nth_unstable_by(k - 1, |&a, &b| {
            residual[b as usize].abs().total_cmp(&residual[a as usize].abs())
        });
        out.d = d;
        out.idx.clear();
        out.idx.extend_from_slice(&order[..k]);
        out.idx.sort_unstable();
        out.val.clear();
        out.val.extend(out.idx.iter().map(|&i| residual[i as usize]));
        // Clear transmitted coordinates from the residual.
        for &i in &out.idx {
            residual[i as usize] = 0.0;
        }
    }

    /// Current residual mass (diagnostics / tests).
    pub fn residual_norm(&self) -> f64 {
        crate::util::math::l2_norm(&self.residual)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn qsgd_roundtrip_is_unbiased() {
        let q = QsgdQuantizer::new(4);
        let g: Vec<f32> = (0..64).map(|i| ((i as f32 * 0.7).sin()) * 0.1).collect();
        let mut rng = Rng::new(3);
        let mut mean = vec![0.0f64; g.len()];
        let trials = 3000;
        let mut out = vec![0.0f32; g.len()];
        for _ in 0..trials {
            let enc = q.encode(&g, &mut rng);
            q.decode(&enc, &mut out);
            for (m, &v) in mean.iter_mut().zip(&out) {
                *m += v as f64 / trials as f64;
            }
        }
        for (i, (&m, &v)) in mean.iter().zip(&g).enumerate() {
            assert!((m - v as f64).abs() < 0.01, "coord {i}: {m} vs {v}");
        }
    }

    #[test]
    fn qsgd_levels_bounded() {
        prop::check("qsgd levels within [-s, s]", 50, |gen| {
            let g = gen.vec_normal(1..300, 2.0);
            let s = *gen.choose(&[1u8, 2, 4, 15]);
            let q = QsgdQuantizer::new(s);
            let enc = q.encode(&g, gen.rng());
            prop::assert_that(
                enc.levels.iter().all(|&l| l.unsigned_abs() <= s),
                "level out of range",
            )
        });
    }

    #[test]
    fn qsgd_wire_bytes() {
        // s=1 → 3 symbols → 2 bits/coord.
        assert_eq!(QsgdQuantizer::new(1).wire_bytes(1000), 4 + 250);
        // s=4 → 9 symbols → 4 bits/coord.
        assert_eq!(QsgdQuantizer::new(4).wire_bytes(1000), 4 + 500);
        // dense f32 would be 4000 — ≥8x reduction at s=4.
    }

    #[test]
    fn qsgd_unbiased_in_expectation_prop() {
        // E[decode(encode(g))] = g for random directions and random level
        // counts — the Alistarh et al. Lemma 3.1 property, checked
        // statistically: the per-coordinate estimator error is bounded by
        // ‖g‖/s per trial, so the K-trial mean is within ~6·‖g‖/(s·√K) of
        // the truth with overwhelming probability.
        prop::check("qsgd unbiasedness", 4, |gen| {
            let d = gen.usize_in(4..32);
            let g = gen.vec_normal(d..d + 1, 1.0);
            let s = *gen.choose(&[2u8, 4, 15]);
            let q = QsgdQuantizer::new(s);
            let norm = crate::util::math::l2_norm(&g);
            let trials = 2000u64;
            let mut mean = vec![0.0f64; g.len()];
            let mut out = vec![0.0f32; g.len()];
            for _ in 0..trials {
                let enc = q.encode(&g, gen.rng());
                q.decode(&enc, &mut out);
                for (m, &v) in mean.iter_mut().zip(&out) {
                    *m += v as f64 / trials as f64;
                }
            }
            let tol = 6.0 * norm / (s as f64 * (trials as f64).sqrt()) + 1e-6;
            for (i, (&m, &v)) in mean.iter().zip(&g).enumerate() {
                prop::assert_that(
                    (m - v as f64).abs() < tol,
                    format!("coord {i}: mean {m} vs {v} (tol {tol})"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn qsgd_decode_finite_for_all_finite_inputs() {
        // The satellite invariant: all-finite input ⇒ all-finite roundtrip,
        // including extreme magnitudes whose f32 norm saturates.
        prop::check("qsgd finite roundtrip", 60, |gen| {
            let mut g = gen.vec_f32(2..200, -1e30..1e30);
            // f32::MAX forces a saturated norm AND a max-level coordinate —
            // the pair that overflows a pure-f32 decode.
            g[0] = f32::MAX;
            g[1] = -3.0e38;
            let s = *gen.choose(&[1u8, 4, 15, 127]);
            let q = QsgdQuantizer::new(s);
            let enc = q.encode(&g, gen.rng());
            prop::assert_that(enc.norm.is_finite(), "norm not finite")?;
            let mut out = vec![0.0f32; g.len()];
            q.decode(&enc, &mut out);
            prop::assert_that(
                out.iter().all(|v| v.is_finite()),
                "non-finite decode",
            )
        });
    }

    #[test]
    fn qsgd_nonfinite_coordinates_encode_to_zero() {
        let q = QsgdQuantizer::new(4);
        let mut rng = Rng::new(2);
        let g = [1.0f32, f32::NAN, -2.0, f32::INFINITY, 0.5, f32::NEG_INFINITY];
        let enc = q.encode(&g, &mut rng);
        assert!(enc.norm.is_finite());
        assert_eq!(enc.levels[1], 0);
        assert_eq!(enc.levels[3], 0);
        assert_eq!(enc.levels[5], 0);
        let mut out = vec![0.0f32; g.len()];
        q.decode(&enc, &mut out);
        assert!(out.iter().all(|v| v.is_finite()));
        assert_eq!(out[1], 0.0);
    }

    #[test]
    fn topk_conserves_mass_prop() {
        // Error-feedback invariant under random gradient streams: per
        // coordinate, transmitted + residual equals the total mass fed in
        // (up to f32 accumulation noise) for any keep fraction.
        prop::check("topk mass conservation", 40, |gen| {
            let d = gen.usize_in(2..128);
            let keep = *gen.choose(&[0.05f64, 0.25, 1.0]);
            let mut sp = TopKSparsifier::new(d, keep);
            let rounds = 20;
            let mut sent = vec![0.0f64; d];
            let mut total = vec![0.0f64; d];
            for _ in 0..rounds {
                let g = gen.vec_normal(d..d + 1, 1.0);
                for (t, &v) in total.iter_mut().zip(&g) {
                    *t += v as f64;
                }
                let msg = sp.encode(&g);
                for (&i, &v) in msg.idx.iter().zip(&msg.val) {
                    sent[i as usize] += v as f64;
                }
            }
            for i in 0..d {
                let conserved = sent[i] + sp.residual[i] as f64;
                let err = (conserved - total[i]).abs();
                prop::assert_that(
                    err < 1e-3 * (1.0 + total[i].abs()),
                    format!("coord {i}: {conserved} vs {} (err {err})", total[i]),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn qsgd_zero_vector() {
        let q = QsgdQuantizer::new(4);
        let mut rng = Rng::new(1);
        let enc = q.encode(&[0.0; 16], &mut rng);
        assert_eq!(enc.norm, 0.0);
        assert!(enc.levels.iter().all(|&l| l == 0));
    }

    #[test]
    fn topk_keeps_largest_and_feeds_back_error() {
        let mut sp = TopKSparsifier::new(8, 0.25); // k = 2
        let g = [0.1f32, -5.0, 0.2, 3.0, 0.0, 0.05, -0.1, 0.3];
        let msg = sp.encode(&g);
        assert_eq!(msg.idx, vec![1, 3]);
        assert_eq!(msg.val, vec![-5.0, 3.0]);
        // Residual holds everything else.
        assert!(sp.residual_norm() > 0.0);
        // Next round with zero gradient transmits the biggest leftovers.
        let msg2 = sp.encode(&[0.0; 8]);
        assert_eq!(msg2.idx, vec![2, 7]);
    }

    #[test]
    fn topk_error_feedback_conserves_mass() {
        // The error-feedback invariant: transmitted + residual == total
        // gradient mass, EXACTLY, per coordinate — nothing is ever lost
        // (this is what makes sparsified SGD converge; Stich et al. 2018).
        let d = 32;
        let mut sp = TopKSparsifier::new(d, 0.125); // k = 4
        let g: Vec<f32> = (0..d).map(|i| (i as f32 + 1.0) / d as f32).collect();
        let rounds = 200;
        let mut total = vec![0.0f32; d];
        for _ in 0..rounds {
            let msg = sp.encode(&g);
            for (&i, &v) in msg.idx.iter().zip(&msg.val) {
                total[i as usize] += v;
            }
        }
        for i in 0..d {
            let conserved = total[i] + sp.residual[i];
            let want = g[i] * rounds as f32;
            assert!(
                (conserved - want).abs() < want * 1e-4 + 1e-3,
                "coord {i}: {conserved} vs {want}"
            );
        }
        // And the residual is bounded (coordinates do get flushed): after
        // d/k extra zero-gradient rounds everything has been sent.
        for _ in 0..(d / 4) {
            let msg = sp.encode(&[0.0; 32]);
            for (&i, &v) in msg.idx.iter().zip(&msg.val) {
                total[i as usize] += v;
            }
        }
        assert!(sp.residual_norm() < 1e-6, "residual {}", sp.residual_norm());
    }

    #[test]
    fn sparse_wire_bytes_and_dense() {
        let msg = SparseGrad { d: 10, idx: vec![2, 7], val: vec![1.5, -2.0] };
        assert_eq!(msg.wire_bytes(), 16);
        let dense = msg.to_dense();
        assert_eq!(dense[2], 1.5);
        assert_eq!(dense[7], -2.0);
        assert_eq!(dense.iter().filter(|&&v| v != 0.0).count(), 2);
    }

    #[test]
    fn topk_full_keep_is_dense_identity() {
        let mut sp = TopKSparsifier::new(6, 1.0);
        let g = [1.0f32, -2.0, 3.0, 0.5, 0.0, -0.1];
        let dense = sp.encode(&g).to_dense();
        assert_eq!(dense.to_vec(), g.to_vec());
        assert_eq!(sp.residual_norm(), 0.0);
    }
}
