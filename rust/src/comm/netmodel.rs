//! α–β network cost model for the communication substrate.
//!
//! The paper measures a real V100 cluster; we reproduce the *cost structure*
//! (DESIGN.md §3): a synchronization round of `v` vectors of `bytes` each
//! across `n` workers costs
//!
//! * **Parameter server** (the paper's architecture, §2): every worker
//!   pushes to and pulls from the server. The server's ingress/egress link
//!   is shared, so an incast of n concurrent senders serialises:
//!   `t = 2·(α + n·bytes / β_server)` per vector (push + pull).
//! * **Ring all-reduce** (the common alternative): `2(n−1)` pipelined steps
//!   moving `bytes/n` chunks: `t = 2(n−1)·α + 2·(n−1)/n · bytes / β`.
//!
//! α (latency) and β (bandwidth) are per-link constants from
//! [`crate::sim::calib`]. All times are seconds, bytes are payload only
//! (framing overhead folds into α).

use crate::config::NetConfig;

/// Communication topology.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Centralised parameter server (paper's setting).
    ParameterServer,
    /// Ring all-reduce (MPI/NCCL style).
    RingAllReduce,
}

impl Topology {
    /// Parse config spelling ("ps" / "allreduce").
    pub fn parse(s: &str) -> Option<Topology> {
        match s {
            "ps" => Some(Topology::ParameterServer),
            "allreduce" => Some(Topology::RingAllReduce),
            _ => None,
        }
    }
}

/// The calibrated cost model.
#[derive(Clone, Debug)]
pub struct NetModel {
    /// Collective topology the costs are computed for.
    pub topology: Topology,
    /// Per-message latency α, seconds.
    pub alpha_s: f64,
    /// Per-link bandwidth β, bytes/second.
    pub beta_bytes_per_s: f64,
    /// Server ingress/egress bandwidth (PS incast), bytes/second.
    pub server_beta_bytes_per_s: f64,
}

impl NetModel {
    /// From the experiment config (validates topology).
    pub fn from_config(cfg: &NetConfig) -> Self {
        let topology = Topology::parse(&cfg.topology)
            .expect("config validation guarantees topology");
        NetModel {
            topology,
            alpha_s: cfg.latency_us * 1e-6,
            beta_bytes_per_s: cfg.bandwidth_gbps * 1e9 / 8.0,
            server_beta_bytes_per_s: cfg.server_bandwidth_gbps * 1e9 / 8.0,
        }
    }

    /// Time for one point-to-point transfer of `bytes`.
    pub fn p2p_time(&self, bytes: u64) -> f64 {
        self.alpha_s + bytes as f64 / self.beta_bytes_per_s
    }

    /// Time for one synchronization round: `n` workers exchanging `vectors`
    /// vectors of `bytes_per_vector` each (average + broadcast).
    ///
    /// Returns 0 for n == 1 (nothing to exchange).
    pub fn sync_time(&self, n: usize, bytes_per_vector: u64, vectors: u64) -> f64 {
        if n <= 1 || vectors == 0 || bytes_per_vector == 0 {
            return 0.0;
        }
        let payload = (bytes_per_vector * vectors) as f64;
        match self.topology {
            Topology::ParameterServer => {
                // Push: n workers into the shared server link, serialised.
                // Pull: server broadcasts back over the same shared link.
                2.0 * (self.alpha_s + n as f64 * payload / self.server_beta_bytes_per_s)
            }
            Topology::RingAllReduce => {
                let n = n as f64;
                2.0 * (n - 1.0) * self.alpha_s
                    + 2.0 * (n - 1.0) / n * payload / self.beta_bytes_per_s
            }
        }
    }

    /// Time for one collective round that moves `total_bytes` cluster-wide
    /// (already summed over directions and participants) — the first-order
    /// α–β cost used by transports whose payload is not a fixed number of
    /// dense vectors (compressed collectives report exact wire bytes and
    /// charge them here; DESIGN.md §3).
    pub fn bytes_time(&self, n: usize, total_bytes: u64) -> f64 {
        if n <= 1 || total_bytes == 0 {
            return 0.0;
        }
        match self.topology {
            Topology::ParameterServer => {
                2.0 * self.alpha_s + total_bytes as f64 / self.server_beta_bytes_per_s
            }
            Topology::RingAllReduce => {
                2.0 * (n as f64 - 1.0) * self.alpha_s
                    + total_bytes as f64 / self.beta_bytes_per_s
            }
        }
    }

    /// Modeled spread between the first and the last worker completing the
    /// push phase of a round whose per-worker payload is `bytes` — the
    /// straggler signal [`crate::coordinator::sync::SyncObservation`]
    /// carries to adaptive sync policies (DESIGN.md §5).
    ///
    /// Under PS incast the n concurrent pushes serialise on the server
    /// link: the first finishes after `B/β_server`, the last after
    /// `n·B/β_server`, so the spread is `(n−1)·B/β_server`. A ring
    /// all-reduce is bulk-synchronous (every worker advances in lockstep
    /// through the 2(n−1) pipeline steps), so its spread is 0.
    pub fn straggler_spread_s(&self, n: usize, bytes: u64) -> f64 {
        if n <= 1 || bytes == 0 {
            return 0.0;
        }
        match self.topology {
            Topology::ParameterServer => {
                (n as f64 - 1.0) * bytes as f64 / self.server_beta_bytes_per_s
            }
            Topology::RingAllReduce => 0.0,
        }
    }

    /// Total bytes moved cluster-wide in one sync round (for accounting
    /// the paper's 2/H traffic-reduction claim, independent of timing).
    pub fn sync_traffic_bytes(&self, n: usize, bytes_per_vector: u64, vectors: u64) -> u64 {
        if n <= 1 {
            return 0;
        }
        let payload = bytes_per_vector * vectors;
        match self.topology {
            // push n·B up + pull n·B down
            Topology::ParameterServer => 2 * n as u64 * payload,
            // 2(n-1) chunks of B/n per worker, n workers
            Topology::RingAllReduce => {
                (2 * (n as u64 - 1)) * payload
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetConfig;
    use crate::util::prop;

    fn model(topo: &str) -> NetModel {
        let cfg = NetConfig { topology: topo.into(), ..Default::default() };
        NetModel::from_config(&cfg)
    }

    #[test]
    fn p2p_is_alpha_plus_size_over_beta() {
        let m = model("ps");
        // defaults: 50us, 1056 Gbit/s = 132e9 B/s
        let t = m.p2p_time(132_000_000);
        assert!((t - (50e-6 + 1e-3)).abs() < 1e-9, "{t}");
    }

    #[test]
    fn single_worker_syncs_free() {
        for topo in ["ps", "allreduce"] {
            assert_eq!(model(topo).sync_time(1, 1 << 20, 2), 0.0);
            assert_eq!(model(topo).sync_traffic_bytes(1, 1 << 20, 2), 0);
        }
    }

    #[test]
    fn ps_incast_grows_linearly_with_n() {
        let m = model("ps");
        let b = 4 * 1_000_000u64;
        let t2 = m.sync_time(2, b, 1);
        let t8 = m.sync_time(8, b, 1);
        // Remove the 2α constant, then the ratio must be exactly 4.
        let c = 2.0 * m.alpha_s;
        assert!(((t8 - c) / (t2 - c) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn allreduce_bandwidth_term_saturates() {
        // (n-1)/n → 1: doubling n beyond a few workers barely changes the
        // bandwidth term — the scalability argument for all-reduce.
        let m = model("allreduce");
        let b = 400 * 1_000_000u64;
        let t4 = m.sync_time(4, b, 1) - 2.0 * 3.0 * m.alpha_s;
        let t8 = m.sync_time(8, b, 1) - 2.0 * 7.0 * m.alpha_s;
        let ratio = t8 / t4;
        assert!(ratio < 1.2, "ratio {ratio}");
    }

    #[test]
    fn two_vectors_cost_double_payload() {
        // Local AdaAlter ships params AND denominators (2 vectors).
        let m = model("ps");
        let t1 = m.sync_time(8, 1 << 22, 1);
        let t2 = m.sync_time(8, 1 << 22, 2);
        let c = 2.0 * m.alpha_s;
        assert!(((t2 - c) - 2.0 * (t1 - c)).abs() < 1e-9);
    }

    #[test]
    fn traffic_accounting() {
        let m = model("ps");
        // 8 workers, 1 MiB vector, 2 vectors: push 16 MiB + pull 16 MiB.
        assert_eq!(m.sync_traffic_bytes(8, 1 << 20, 2), 32 << 20);
        let r = model("allreduce");
        assert_eq!(r.sync_traffic_bytes(8, 1 << 20, 2), 14 << 21);
    }

    #[test]
    fn bytes_time_first_order() {
        let m = model("ps");
        assert_eq!(m.bytes_time(1, 1 << 20), 0.0);
        assert_eq!(m.bytes_time(8, 0), 0.0);
        let t = m.bytes_time(8, 132_000_000_000);
        assert!((t - (2.0 * 50e-6 + 1.0)).abs() < 1e-9, "{t}");
        let r = model("allreduce");
        let t = r.bytes_time(4, 132_000_000_000);
        assert!((t - (6.0 * 50e-6 + 1.0)).abs() < 1e-9, "{t}");
    }

    #[test]
    fn straggler_spread_shapes() {
        let m = model("ps");
        // (n−1)·B/β_server: 8 workers, 132 MB payload → 7 ms exactly.
        let s = m.straggler_spread_s(8, 132_000_000);
        assert!((s - 7e-3).abs() < 1e-12, "{s}");
        assert_eq!(m.straggler_spread_s(1, 1 << 20), 0.0);
        assert_eq!(m.straggler_spread_s(8, 0), 0.0);
        // Ring is bulk-synchronous: no modeled spread.
        assert_eq!(model("allreduce").straggler_spread_s(8, 1 << 20), 0.0);
    }

    #[test]
    fn properties_monotonicity() {
        prop::check("netmodel monotone in n, bytes, vectors", 200, |g| {
            let m = if g.bool() { model("ps") } else { model("allreduce") };
            let n = g.usize_in(2..16);
            let b = g.u64_in(1..1 << 24);
            let v = g.u64_in(1..3);
            let t = m.sync_time(n, b, v);
            prop::assert_that(t > 0.0, "positive")?;
            prop::assert_that(
                m.sync_time(n + 1, b, v) >= t,
                "monotone in n",
            )?;
            prop::assert_that(
                m.sync_time(n, b + 1024, v) >= t,
                "monotone in bytes",
            )?;
            prop::assert_that(m.sync_time(n, b, v + 1) >= t, "monotone in vectors")
        });
    }
}
