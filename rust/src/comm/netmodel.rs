//! α–β network cost model for the communication substrate.
//!
//! The paper measures a real V100 cluster; we reproduce the *cost structure*
//! (DESIGN.md §3): a synchronization round of `v` vectors of `bytes` each
//! across `n` workers costs
//!
//! * **Parameter server** (the paper's architecture, §2): every worker
//!   pushes to and pulls from the server. The server's ingress/egress link
//!   is shared, so an incast of n concurrent senders serialises:
//!   `t = 2·(α + n·bytes / β_server)` per vector (push + pull). With
//!   `k` leader shards (range partition of the vector, `comm.shards`),
//!   the k shard servers absorb the incast in parallel and the critical
//!   path carries `bytes/k`: `t = 2·(α + n·(bytes/k) / β_server)`.
//! * **Ring all-reduce** (the common alternative): `2(n−1)` pipelined steps
//!   moving `bytes/n` chunks: `t = 2(n−1)·α + 2·(n−1)/n · bytes / β`.
//! * **Tree all-reduce** (hierarchical reduce + broadcast over a fan-out-f
//!   tree, `net.tree_fanout`): `L = ⌈log_f n⌉` levels; at each level a
//!   parent absorbs f children serially on its link, once up (reduce) and
//!   once down (broadcast): `t = 2L·(α + f·bytes / β)`.
//!
//! α (latency) and β (bandwidth) are per-link constants from
//! [`crate::sim::calib`]. All times are seconds, bytes are payload only
//! (framing overhead folds into α).
//!
//! Every topology keeps `bytes_time` (the charge for transports that
//! report exact wire bytes) consistent with `sync_time`: feeding a round's
//! own `sync_traffic_bytes` back into `bytes_time` reproduces the
//! `sync_time` bandwidth term exactly — pinned by a property test below.

use crate::config::NetConfig;

/// Communication topology.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Centralised parameter server (paper's setting).
    ParameterServer,
    /// Ring all-reduce (MPI/NCCL style).
    RingAllReduce,
    /// Hierarchical reduce + broadcast over a fan-out-f tree.
    TreeAllReduce,
}

impl Topology {
    /// Parse config spelling ("ps" / "allreduce" / "tree").
    pub fn parse(s: &str) -> Option<Topology> {
        match s {
            "ps" => Some(Topology::ParameterServer),
            "allreduce" => Some(Topology::RingAllReduce),
            "tree" => Some(Topology::TreeAllReduce),
            _ => None,
        }
    }
}

/// Tree depth `⌈log_f n⌉`: levels needed for a fan-out-`f` tree to span
/// `n` nodes (0 for n ≤ 1). Computed by integer doubling — no float logs.
pub fn tree_depth(n: usize, fanout: usize) -> u32 {
    let f = fanout.max(2);
    let mut levels = 0u32;
    let mut reach = 1usize;
    while reach < n {
        reach = reach.saturating_mul(f);
        levels += 1;
    }
    levels
}

/// The calibrated cost model.
#[derive(Clone, Debug)]
pub struct NetModel {
    /// Collective topology the costs are computed for.
    pub topology: Topology,
    /// Per-message latency α, seconds.
    pub alpha_s: f64,
    /// Per-link bandwidth β, bytes/second.
    pub beta_bytes_per_s: f64,
    /// Server ingress/egress bandwidth (PS incast), bytes/second.
    pub server_beta_bytes_per_s: f64,
    /// Leader shards k (PS only): the incast serialises over `bytes/k`
    /// per shard server. 1 = single leader (the pre-sharding model).
    pub shards: usize,
    /// Tree topology fan-out f (children per node, ≥ 2).
    pub tree_fanout: usize,
}

impl NetModel {
    /// From the experiment config (validates topology). Shards default
    /// to 1 — thread `comm.shards` in via [`NetModel::with_shards`].
    pub fn from_config(cfg: &NetConfig) -> Self {
        let topology = Topology::parse(&cfg.topology)
            .expect("config validation guarantees topology");
        NetModel {
            topology,
            alpha_s: cfg.latency_us * 1e-6,
            beta_bytes_per_s: cfg.bandwidth_gbps * 1e9 / 8.0,
            server_beta_bytes_per_s: cfg.server_bandwidth_gbps * 1e9 / 8.0,
            shards: 1,
            tree_fanout: cfg.tree_fanout.max(2),
        }
    }

    /// Set the leader shard count (`comm.shards`); k = 1 leaves every
    /// cost bitwise-identical to the unsharded model.
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }

    /// Time for one point-to-point transfer of `bytes`.
    pub fn p2p_time(&self, bytes: u64) -> f64 {
        self.alpha_s + bytes as f64 / self.beta_bytes_per_s
    }

    /// Time for one synchronization round: `n` workers exchanging `vectors`
    /// vectors of `bytes_per_vector` each (average + broadcast).
    ///
    /// Returns 0 for n == 1 (nothing to exchange).
    pub fn sync_time(&self, n: usize, bytes_per_vector: u64, vectors: u64) -> f64 {
        if n <= 1 || vectors == 0 || bytes_per_vector == 0 {
            return 0.0;
        }
        let payload = (bytes_per_vector * vectors) as f64;
        match self.topology {
            Topology::ParameterServer => {
                // Push: n workers into each shard server's link, serialised;
                // the k shards run in parallel so the critical path carries
                // the per-shard slice. Pull: same link back down.
                let shard_payload = payload / self.shards as f64;
                2.0 * (self.alpha_s
                    + n as f64 * shard_payload / self.server_beta_bytes_per_s)
            }
            Topology::RingAllReduce => {
                let n = n as f64;
                2.0 * (n - 1.0) * self.alpha_s
                    + 2.0 * (n - 1.0) / n * payload / self.beta_bytes_per_s
            }
            Topology::TreeAllReduce => {
                // L levels up (reduce) + L levels down (broadcast); at each
                // level a parent's link serialises its f children.
                let l = tree_depth(n, self.tree_fanout) as f64;
                2.0 * l
                    * (self.alpha_s
                        + self.tree_fanout as f64 * payload / self.beta_bytes_per_s)
            }
        }
    }

    /// Time for one collective round that moves `total_bytes` cluster-wide
    /// (already summed over directions and participants) — the first-order
    /// α–β cost used by transports whose payload is not a fixed number of
    /// dense vectors (compressed collectives report exact wire bytes and
    /// charge them here; DESIGN.md §3).
    ///
    /// Consistent with [`NetModel::sync_time`] by construction:
    /// `bytes_time(n, sync_traffic_bytes(n, b, v))` has exactly the
    /// `sync_time(n, b, v)` bandwidth term under every topology.
    pub fn bytes_time(&self, n: usize, total_bytes: u64) -> f64 {
        if n <= 1 || total_bytes == 0 {
            return 0.0;
        }
        match self.topology {
            Topology::ParameterServer => {
                // total = 2n·B; per shard server the critical path is
                // total/k, matching the sharded sync_time incast.
                2.0 * self.alpha_s
                    + (total_bytes as f64 / self.shards as f64)
                        / self.server_beta_bytes_per_s
            }
            Topology::RingAllReduce => {
                // total = 2(n−1)·B and the pipelined bandwidth term is
                // 2(n−1)/n·B/β = total/(n·β) — the same pipelining factor
                // sync_time charges (dense and compressed payloads must
                // cost the same per byte).
                2.0 * (n as f64 - 1.0) * self.alpha_s
                    + total_bytes as f64 / (n as f64 * self.beta_bytes_per_s)
            }
            Topology::TreeAllReduce => {
                // total = 2(n−1)·B; the per-level serialised term is
                // f·B/β per direction, so L·f·total/((n−1)·β) overall.
                let l = tree_depth(n, self.tree_fanout) as f64;
                2.0 * l * self.alpha_s
                    + l * self.tree_fanout as f64 * total_bytes as f64
                        / ((n as f64 - 1.0) * self.beta_bytes_per_s)
            }
        }
    }

    /// Modeled spread between the first and the last worker completing the
    /// push phase of a round whose per-worker payload is `bytes` — the
    /// straggler signal [`crate::coordinator::sync::SyncObservation`]
    /// carries to adaptive sync policies (DESIGN.md §5).
    ///
    /// Under PS incast the n concurrent pushes serialise on the (per-shard)
    /// server link: the first finishes after `(B/k)/β_server`, the last
    /// after `n·(B/k)/β_server`, so the spread is `(n−1)·(B/k)/β_server`.
    /// A ring all-reduce is bulk-synchronous (every worker advances in
    /// lockstep through the 2(n−1) pipeline steps), so its spread is 0.
    /// In a tree each parent drains f children serially per level:
    /// spread `(f−1)·B/β` per level, `L·(f−1)·B/β` end to end.
    pub fn straggler_spread_s(&self, n: usize, bytes: u64) -> f64 {
        if n <= 1 || bytes == 0 {
            return 0.0;
        }
        match self.topology {
            Topology::ParameterServer => {
                (n as f64 - 1.0) * (bytes as f64 / self.shards as f64)
                    / self.server_beta_bytes_per_s
            }
            Topology::RingAllReduce => 0.0,
            Topology::TreeAllReduce => {
                let l = tree_depth(n, self.tree_fanout) as f64;
                l * (self.tree_fanout as f64 - 1.0) * bytes as f64
                    / self.beta_bytes_per_s
            }
        }
    }

    /// Total bytes moved cluster-wide in one sync round (for accounting
    /// the paper's 2/H traffic-reduction claim, independent of timing).
    ///
    /// Shard-invariant: a range partition moves the same bytes, just over
    /// k links — per-shard accounting sums back to exactly these totals.
    pub fn sync_traffic_bytes(&self, n: usize, bytes_per_vector: u64, vectors: u64) -> u64 {
        if n <= 1 {
            return 0;
        }
        let payload = bytes_per_vector * vectors;
        match self.topology {
            // push n·B up + pull n·B down
            Topology::ParameterServer => 2 * n as u64 * payload,
            // 2(n-1) chunks of B/n per worker, n workers
            Topology::RingAllReduce => (2 * (n as u64 - 1)) * payload,
            // n−1 tree edges, each carrying B up (reduce) + B down
            // (broadcast) — same total as the ring, spent in L levels.
            Topology::TreeAllReduce => (2 * (n as u64 - 1)) * payload,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetConfig;
    use crate::util::prop;

    fn model(topo: &str) -> NetModel {
        let cfg = NetConfig { topology: topo.into(), ..Default::default() };
        NetModel::from_config(&cfg)
    }

    #[test]
    fn p2p_is_alpha_plus_size_over_beta() {
        let m = model("ps");
        // defaults: 50us, 1056 Gbit/s = 132e9 B/s
        let t = m.p2p_time(132_000_000);
        assert!((t - (50e-6 + 1e-3)).abs() < 1e-9, "{t}");
    }

    #[test]
    fn single_worker_syncs_free() {
        for topo in ["ps", "allreduce", "tree"] {
            assert_eq!(model(topo).sync_time(1, 1 << 20, 2), 0.0);
            assert_eq!(model(topo).sync_traffic_bytes(1, 1 << 20, 2), 0);
        }
    }

    #[test]
    fn ps_incast_grows_linearly_with_n() {
        let m = model("ps");
        let b = 4 * 1_000_000u64;
        let t2 = m.sync_time(2, b, 1);
        let t8 = m.sync_time(8, b, 1);
        // Remove the 2α constant, then the ratio must be exactly 4.
        let c = 2.0 * m.alpha_s;
        assert!(((t8 - c) / (t2 - c) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn sharded_ps_divides_the_incast() {
        let m = model("ps");
        let k4 = model("ps").with_shards(4);
        let b = 132_000_000u64;
        let c = 2.0 * m.alpha_s;
        let t1 = m.sync_time(32, b, 1) - c;
        let t4 = k4.sync_time(32, b, 1) - c;
        // k shard servers absorb the incast in parallel: exactly k× faster
        // past the latency constant.
        assert!((t1 / t4 - 4.0).abs() < 1e-9, "{t1} {t4}");
        // Same division in the first-order byte charge and the straggler
        // spread; traffic totals are shard-invariant.
        let total = m.sync_traffic_bytes(32, b, 1);
        assert_eq!(total, k4.sync_traffic_bytes(32, b, 1));
        let bt1 = m.bytes_time(32, total) - c;
        let bt4 = k4.bytes_time(32, total) - c;
        assert!((bt1 / bt4 - 4.0).abs() < 1e-9);
        assert!((m.straggler_spread_s(32, b) / k4.straggler_spread_s(32, b) - 4.0).abs() < 1e-9);
        // with_shards(1) is the identity — bitwise.
        let id = model("ps").with_shards(1);
        assert_eq!(id.sync_time(32, b, 1).to_bits(), m.sync_time(32, b, 1).to_bits());
        assert_eq!(id.bytes_time(32, total).to_bits(), m.bytes_time(32, total).to_bits());
        assert_eq!(
            id.straggler_spread_s(32, b).to_bits(),
            m.straggler_spread_s(32, b).to_bits()
        );
    }

    #[test]
    fn allreduce_bandwidth_term_saturates() {
        // (n-1)/n → 1: doubling n beyond a few workers barely changes the
        // bandwidth term — the scalability argument for all-reduce.
        let m = model("allreduce");
        let b = 400 * 1_000_000u64;
        let t4 = m.sync_time(4, b, 1) - 2.0 * 3.0 * m.alpha_s;
        let t8 = m.sync_time(8, b, 1) - 2.0 * 7.0 * m.alpha_s;
        let ratio = t8 / t4;
        assert!(ratio < 1.2, "ratio {ratio}");
    }

    #[test]
    fn tree_depth_is_ceil_log() {
        assert_eq!(tree_depth(1, 2), 0);
        assert_eq!(tree_depth(2, 2), 1);
        assert_eq!(tree_depth(3, 2), 2);
        assert_eq!(tree_depth(8, 2), 3);
        assert_eq!(tree_depth(9, 2), 4);
        assert_eq!(tree_depth(64, 4), 3);
        assert_eq!(tree_depth(65, 4), 4);
        assert_eq!(tree_depth(1000, 10), 3);
    }

    #[test]
    fn tree_costs_grow_logarithmically() {
        let m = model("tree");
        let b = 132_000_000u64;
        // sync_time = 2L(α + f·B/β): n = 8 → L = 3, n = 64 → L = 6 at
        // f = 2 — doubling depth, not 8× incast.
        let t8 = m.sync_time(8, b, 1);
        let t64 = m.sync_time(64, b, 1);
        assert!((t64 / t8 - 2.0).abs() < 1e-9, "{t8} {t64}");
        // Closed form at n = 8, f = 2: 6(α + 2·0.001) = 6α + 0.012.
        assert!((t8 - (6.0 * m.alpha_s + 0.012)).abs() < 1e-12, "{t8}");
        // Straggler spread: L(f−1)B/β = 3·0.001 at n = 8.
        let s = m.straggler_spread_s(8, b);
        assert!((s - 3e-3).abs() < 1e-12, "{s}");
    }

    #[test]
    fn two_vectors_cost_double_payload() {
        // Local AdaAlter ships params AND denominators (2 vectors).
        let m = model("ps");
        let t1 = m.sync_time(8, 1 << 22, 1);
        let t2 = m.sync_time(8, 1 << 22, 2);
        let c = 2.0 * m.alpha_s;
        assert!(((t2 - c) - 2.0 * (t1 - c)).abs() < 1e-9);
    }

    #[test]
    fn traffic_accounting() {
        let m = model("ps");
        // 8 workers, 1 MiB vector, 2 vectors: push 16 MiB + pull 16 MiB.
        assert_eq!(m.sync_traffic_bytes(8, 1 << 20, 2), 32 << 20);
        let r = model("allreduce");
        assert_eq!(r.sync_traffic_bytes(8, 1 << 20, 2), 14 << 21);
        // Tree moves the ring's total (n−1 edges × up + down), in L levels.
        let t = model("tree");
        assert_eq!(t.sync_traffic_bytes(8, 1 << 20, 2), 14 << 21);
    }

    #[test]
    fn bytes_time_first_order() {
        let m = model("ps");
        assert_eq!(m.bytes_time(1, 1 << 20), 0.0);
        assert_eq!(m.bytes_time(8, 0), 0.0);
        let t = m.bytes_time(8, 132_000_000_000);
        assert!((t - (2.0 * 50e-6 + 1.0)).abs() < 1e-9, "{t}");
        // Ring: the bandwidth term carries the same 2(n−1)/n pipelining
        // factor as sync_time — total/(n·β), NOT total/β. 132 GB over
        // n = 4 → 0.25 s.
        let r = model("allreduce");
        let t = r.bytes_time(4, 132_000_000_000);
        assert!((t - (6.0 * 50e-6 + 0.25)).abs() < 1e-9, "{t}");
        // Tree at n = 4, f = 2 → L = 2: 4α + 2·2·total/(3β) = 4α + 4/3 s.
        let tr = model("tree");
        let t = tr.bytes_time(4, 132_000_000_000);
        assert!((t - (4.0 * 50e-6 + 4.0 / 3.0)).abs() < 1e-9, "{t}");
    }

    #[test]
    fn straggler_spread_shapes() {
        let m = model("ps");
        // (n−1)·B/β_server: 8 workers, 132 MB payload → 7 ms exactly.
        let s = m.straggler_spread_s(8, 132_000_000);
        assert!((s - 7e-3).abs() < 1e-12, "{s}");
        assert_eq!(m.straggler_spread_s(1, 1 << 20), 0.0);
        assert_eq!(m.straggler_spread_s(8, 0), 0.0);
        // Ring is bulk-synchronous: no modeled spread.
        assert_eq!(model("allreduce").straggler_spread_s(8, 1 << 20), 0.0);
    }

    #[test]
    fn properties_monotonicity() {
        prop::check("netmodel monotone in n, bytes, vectors", 300, |g| {
            let m = match g.usize_in(0..3) {
                0 => model("ps"),
                1 => model("allreduce"),
                _ => model("tree"),
            };
            let n = g.usize_in(2..16);
            let b = g.u64_in(1..1 << 24);
            let v = g.u64_in(1..3);
            let t = m.sync_time(n, b, v);
            prop::assert_that(t > 0.0, "positive")?;
            prop::assert_that(
                m.sync_time(n + 1, b, v) >= t,
                "monotone in n",
            )?;
            prop::assert_that(
                m.sync_time(n, b + 1024, v) >= t,
                "monotone in bytes",
            )?;
            prop::assert_that(m.sync_time(n, b, v + 1) >= t, "monotone in vectors")
        });
    }

    #[test]
    fn properties_bytes_time_consistent_with_sync_time() {
        // Feeding a round's own traffic total back through the first-order
        // byte charge must reproduce the sync_time bandwidth term for every
        // topology and shard count — the satellite-1 consistency pin
        // (compressed and dense payloads cost the same per byte).
        prop::check("bytes_time ≡ sync_time on a round's own traffic", 300, |g| {
            let m = match g.usize_in(0..4) {
                0 => model("ps"),
                1 => model("ps").with_shards(1 << g.usize_in(0..4)),
                2 => model("allreduce"),
                _ => model("tree"),
            };
            let n = g.usize_in(2..64);
            let b = g.u64_in(1..1 << 22);
            let v = g.u64_in(1..3);
            // Latency terms are structurally identical on both sides
            // (2α / 2(n−1)α / 2Lα), so compare full times directly.
            let from_sync = m.sync_time(n, b, v);
            let from_bytes = m.bytes_time(n, m.sync_traffic_bytes(n, b, v));
            let rel = (from_sync - from_bytes).abs() / from_sync.max(1e-30);
            prop::assert_that(rel < 1e-9, "bandwidth terms agree")
        });
    }

    #[test]
    fn properties_tree_shape() {
        prop::check("tree: depth/traffic/fan-out laws", 300, |g| {
            let n = g.usize_in(2..128);
            let b = g.u64_in(1..1 << 22);
            let f = 2 + g.usize_in(0..7);
            let cfg = NetConfig {
                topology: "tree".into(),
                tree_fanout: f,
                ..Default::default()
            };
            let m = NetModel::from_config(&cfg);
            // Depth is ⌈log_f n⌉: f^L ≥ n > f^(L−1).
            let l = tree_depth(n, f);
            prop::assert_that(f.pow(l) >= n, "f^L covers n")?;
            prop::assert_that(l == 0 || f.pow(l - 1) < n, "L is minimal")?;
            // Fan-out trades depth for per-level serialisation; depth
            // itself is monotone non-increasing in f…
            prop::assert_that(tree_depth(n, f + 1) <= l, "depth non-increasing in f")?;
            // …while the traffic total is fan-out-invariant and equals the
            // ring total (conservation: n−1 edges, payload up + down).
            let ring = model("allreduce");
            let wider = NetConfig {
                topology: "tree".into(),
                tree_fanout: f + 3,
                ..Default::default()
            };
            let t = m.sync_traffic_bytes(n, b, 2);
            prop::assert_that(
                t == NetModel::from_config(&wider).sync_traffic_bytes(n, b, 2),
                "traffic invariant in fan-out",
            )?;
            prop::assert_that(t == ring.sync_traffic_bytes(n, b, 2), "ring-equal traffic")?;
            // PS moves more: 2n·B vs 2(n−1)·B.
            prop::assert_that(
                model("ps").sync_traffic_bytes(n, b, 2) > t,
                "ps traffic strictly larger",
            )
        });
    }

    #[test]
    fn tree_and_sharded_ps_beat_single_leader_incast() {
        // The ROADMAP item-2 claim, at the model level: by n = 32 the
        // single-leader incast loses to both alternatives.
        let b = 132_000_000u64; // one 33M-param f32 vector
        for n in [32usize, 64, 128] {
            let ps = model("ps").sync_time(n, b, 1);
            let ps4 = model("ps").with_shards(4).sync_time(n, b, 1);
            let tree = model("tree").sync_time(n, b, 1);
            assert!(ps4 < ps, "n={n}: sharded {ps4} !< single {ps}");
            assert!(tree < ps, "n={n}: tree {tree} !< single {ps}");
        }
    }
}
