//! Pluggable collective-communication layer (DESIGN.md §3).
//!
//! The paper's contribution is cutting bytes-on-the-wire, so the wire is a
//! first-class abstraction: a [`Collective`] carries the data-plane ops the
//! training protocol needs — model broadcast, gradient gather, and the
//! paired parameter/denominator averaging round of Alg. 4 lines 11–12 —
//! and *owns the cost accounting* for each op. The trainer asks for the
//! op; the collective returns a [`CommReport`] saying what it cost, and
//! the trainer books that against the virtual clock and the
//! [`crate::metrics::TrainRecorder`].
//!
//! Implementations:
//!
//! * [`ChannelCollective`] — the in-process lockstep data ops (exact means,
//!   identity gathers), zero cost. Preserves the seed trainer bitwise.
//! * [`SimulatedCollective`] — same data ops, but every round is charged
//!   the paper-calibrated α–β cost ([`NetModel`]) at the Big-LSTM payload
//!   scale and its real `4·d` traffic is booked (previously hand-sprinkled
//!   through `Trainer::run`). This is the default transport.
//! * [`CompressedCollective`] — a decorator around the lockstep data ops
//!   that pushes gradients/state deltas through [`QsgdQuantizer`] or
//!   [`TopKSparsifier`] and reports *exact* wire bytes, plus the α–β time
//!   of those bytes. This is the §1 quantization/sparsification baseline
//!   family, runnable through the full trainer.
//! * [`PartialCollective`] — a decorator adding partial-participation
//!   semantics (quorum / backup-worker rounds under a `[faults]` scenario,
//!   DESIGN.md §6) to any of the above.
//!
//! Selection is pure configuration: `[comm]` + `[faults]` in the
//! experiment TOML ([`crate::config::CommConfig`],
//! [`crate::config::FaultsConfig`]) → [`build_collective`].

use crate::comm::compress::{QsgdEncoded, QsgdQuantizer, SparseGrad, TopKSparsifier};
use crate::comm::netmodel::{NetModel, Topology};
use crate::comm::shard::{mean_into_sharded, mean_into_sharded_exec, ShardPlan};
use crate::coordinator::executor::Executor;
use crate::config::ExperimentConfig;
use crate::error::{Error, Result};
use crate::sim::Calibration;
use crate::util::{kernels, math};

/// What one collective op cost — and what it observed while running.
///
/// The observation fields (`drift_sq`, `straggler_s`) feed the adaptive
/// synchronization policies of [`crate::coordinator::sync`] (DESIGN.md
/// §4): the collective is the one place that already holds every worker's
/// vectors and the round's modeled timing, so it reports them alongside
/// the cost.
#[derive(Clone, Copy, Debug, Default)]
pub struct CommReport {
    /// Exact bytes shipped cluster-wide (0 for in-process transports).
    pub bytes: u64,
    /// Modeled wall time of the op, seconds (virtual-clock charge).
    pub time_s: f64,
    /// Synchronization rounds this op completed (drives the recorder's
    /// sync counter; broadcasts fold into their round and report 0).
    pub rounds: u64,
    /// Mean squared L2 distance of the averaged inputs from their mean —
    /// the realized replica drift at a sync round (0 for ops that average
    /// nothing, e.g. gradient gathers).
    pub drift_sq: f64,
    /// Modeled first-to-last-worker completion spread of the round
    /// ([`NetModel::straggler_spread_s`]; 0 for in-process transports).
    pub straggler_s: f64,
}

impl CommReport {
    /// The free op.
    pub fn zero() -> Self {
        CommReport::default()
    }

    /// Combine two reports of the same protocol round. Costs add;
    /// observations keep the worst (largest) value seen.
    pub fn merge(self, other: CommReport) -> CommReport {
        CommReport {
            bytes: self.bytes + other.bytes,
            time_s: self.time_s + other.time_s,
            rounds: self.rounds + other.rounds,
            drift_sq: self.drift_sq.max(other.drift_sq),
            straggler_s: self.straggler_s.max(other.straggler_s),
        }
    }
}

/// Mean over workers of the squared L2 distance `‖x_i − mean‖²` — the
/// replica-drift observation sync rounds report. `pub(crate)`: the
/// networked collective (`comm::net`) reports the same observation.
pub(crate) fn mean_sq_dist(xs: &[&[f32]], mean: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut total = 0.0f64;
    for x in xs {
        for (&a, &m) in x.iter().zip(mean) {
            let d = (a - m) as f64;
            total += d * d;
        }
    }
    total / xs.len() as f64
}

/// The collective ops the training protocol is written against.
///
/// Data-plane contract: ops transform/average the vectors they are handed;
/// lossless transports leave payloads bit-identical, compressed transports
/// replace them with their decode(encode(·)) images. Cost contract: every
/// op returns the bytes/time/rounds it cost; implementations that model no
/// cost return zeros.
pub trait Collective: Send {
    /// Number of participants (workers).
    fn n(&self) -> usize;

    /// Human-readable transport label (metrics / bench tables).
    fn label(&self) -> String;

    /// Leader → workers model broadcast. The payload is mutable because a
    /// lossy wire transforms what the workers receive (the bf16 wire
    /// rounds it onto the bf16 grid in place — exactly the bytes a real
    /// wire would carry); lossless transports leave it untouched. The pull
    /// side of a round is accounted by the round op that triggered it
    /// (matching the paper's push+pull parameter-server accounting), so
    /// this defaults to free.
    fn broadcast(&mut self, _x: &mut [f32]) -> Result<CommReport> {
        Ok(CommReport::zero())
    }

    /// Workers → leader gradient gather (the Alg. 1/3 line-4→5 edge):
    /// transforms each worker's gradient in place and accounts one full
    /// push+pull round.
    fn gather_grads(&mut self, grads: &mut [Vec<f32>]) -> Result<CommReport>;

    /// Fused gather + average + broadcast: `out = mean_i inputs[i]`.
    fn allreduce_mean(&mut self, inputs: &[&[f32]], out: &mut [f32]) -> Result<CommReport>;

    /// The paired sync-round op of Alg. 4 lines 11–12: average parameters
    /// (and, when `accs` is given, accumulated denominators) in one
    /// accounted round. `avg_acc` must be `Some` iff `accs` is.
    fn sync_round(
        &mut self,
        xs: &[&[f32]],
        accs: Option<&[&[f32]]>,
        avg_x: &mut [f32],
        avg_acc: Option<&mut [f32]>,
    ) -> Result<CommReport>;

    /// The sync round with per-worker barrier arrival times and (possibly)
    /// partial participation (DESIGN.md §6). `arrivals[i]` is worker `i`'s
    /// virtual arrival at the barrier, measured from the phase start. The
    /// default implementation is the full barrier: every offered worker
    /// participates and the round closes when the slowest arrives —
    /// [`PartialCollective`] overrides this with quorum / backup-worker
    /// selection.
    fn sync_round_partial(
        &mut self,
        xs: &[&[f32]],
        accs: Option<&[&[f32]]>,
        arrivals: &[f64],
        avg_x: &mut [f32],
        avg_acc: Option<&mut [f32]>,
    ) -> Result<PartialRound> {
        if arrivals.len() != xs.len() {
            return Err(Error::Protocol(format!(
                "sync_round_partial: {} arrivals for {} workers",
                arrivals.len(),
                xs.len()
            )));
        }
        let report = self.sync_round(xs, accs, avg_x, avg_acc)?;
        let close_s = arrivals.iter().fold(0.0f64, |a, &b| a.max(b));
        Ok(PartialRound {
            participants: (0..xs.len()).collect(),
            dropped: Vec::new(),
            close_s,
            report,
        })
    }
}

/// Outcome of one (possibly partial) synchronization round
/// ([`Collective::sync_round_partial`]; DESIGN.md §6).
#[derive(Clone, Debug)]
pub struct PartialRound {
    /// Indices (into the offered `xs`) whose states made the average,
    /// ascending — so the averaging order is deterministic.
    pub participants: Vec<usize>,
    /// Indices dropped as stragglers (they still receive the installed
    /// average — catch-up — but contribute nothing to it).
    pub dropped: Vec<usize>,
    /// Virtual time at which the barrier closed, on the same axis as the
    /// offered arrival times.
    pub close_s: f64,
    /// Cost/observation report of the executed averaging round.
    pub report: CommReport,
}

/// Participation policy for partial sync rounds (the `[faults]` config
/// section's `quorum` / `timeout_s` / `drop_slowest` keys; DESIGN.md §6).
#[derive(Clone, Copy, Debug)]
pub struct Participation {
    /// Minimum arrivals that close a round (0 behaves as "all offered").
    pub quorum: usize,
    /// Extra virtual wait after the quorum arrives before dropping the rest.
    pub timeout_s: f64,
    /// Backup-worker policy: always drop the k slowest arrivals (0 = off).
    pub drop_slowest: usize,
}

impl Participation {
    /// The policy the `[faults]` section selects, if any.
    pub fn from_config(f: &crate::config::FaultsConfig) -> Option<Participation> {
        if f.partial() {
            Some(Participation {
                quorum: f.quorum,
                timeout_s: f.timeout_s,
                drop_slowest: f.drop_slowest,
            })
        } else {
            None
        }
    }

    /// Human-readable policy label (transport labels, bench tables).
    pub fn label(&self) -> String {
        if self.drop_slowest > 0 {
            format!("drop{}", self.drop_slowest)
        } else {
            format!("q{}+{}s", self.quorum, self.timeout_s)
        }
    }

    /// Select the round's participants from per-worker arrival times.
    /// Deterministic: ties break by worker index. Returns
    /// `(participants, dropped, close_s)`, both index lists ascending.
    ///
    /// * **Backup-worker** (`drop_slowest` > 0): the k slowest arrivals are
    ///   always dropped (at least one worker is kept); the barrier closes
    ///   when the slowest *kept* worker arrives.
    /// * **Quorum**: with `t_q` the quorum-th fastest arrival, every worker
    ///   arriving by `t_q + timeout_s` participates; the barrier closes at
    ///   the last participant arrival, or at the full `t_q + timeout_s`
    ///   when someone was dropped (the leader waited the timeout out).
    pub fn select(&self, arrivals: &[f64]) -> Result<(Vec<usize>, Vec<usize>, f64)> {
        let m = arrivals.len();
        if m == 0 {
            return Err(Error::Protocol("partial round with no live workers".into()));
        }
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| {
            arrivals[a]
                .partial_cmp(&arrivals[b])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        if self.drop_slowest > 0 {
            let keep = m.saturating_sub(self.drop_slowest).max(1);
            let mut participants = order[..keep].to_vec();
            let mut dropped = order[keep..].to_vec();
            let close_s = participants.iter().map(|&i| arrivals[i]).fold(0.0, f64::max);
            participants.sort_unstable();
            dropped.sort_unstable();
            return Ok((participants, dropped, close_s));
        }
        // quorum = 0 is the documented full barrier: everyone is required.
        let q = if self.quorum == 0 { m } else { self.quorum };
        if q > m {
            return Err(Error::Protocol(format!(
                "faults.quorum ({q}) unreachable: only {m} workers alive"
            )));
        }
        let t_q = arrivals[order[q - 1]];
        let cutoff = t_q + self.timeout_s;
        let participants: Vec<usize> = (0..m).filter(|&i| arrivals[i] <= cutoff).collect();
        let dropped: Vec<usize> = (0..m).filter(|&i| arrivals[i] > cutoff).collect();
        let close_s = if dropped.is_empty() {
            participants.iter().map(|&i| arrivals[i]).fold(0.0, f64::max)
        } else {
            cutoff
        };
        Ok((participants, dropped, close_s))
    }
}

/// Decorator adding partial-participation semantics to any [`Collective`]:
/// [`Collective::sync_round_partial`] selects the round's participants per
/// the configured [`Participation`] policy, averages *only their* states
/// through the inner collective (so the round cost is billed at the
/// participant count), and reports who was dropped. Every other op — and
/// `sync_round` itself, the full-barrier entry — forwards unchanged.
pub struct PartialCollective {
    inner: Box<dyn Collective>,
    policy: Participation,
}

impl PartialCollective {
    /// Wrap `inner` with the participation policy.
    pub fn new(inner: Box<dyn Collective>, policy: Participation) -> Self {
        PartialCollective { inner, policy }
    }

    /// The configured participation policy.
    pub fn policy(&self) -> Participation {
        self.policy
    }
}

impl Collective for PartialCollective {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn label(&self) -> String {
        format!("partial({}, {})", self.policy.label(), self.inner.label())
    }

    fn broadcast(&mut self, x: &mut [f32]) -> Result<CommReport> {
        self.inner.broadcast(x)
    }

    fn gather_grads(&mut self, grads: &mut [Vec<f32>]) -> Result<CommReport> {
        self.inner.gather_grads(grads)
    }

    fn allreduce_mean(&mut self, inputs: &[&[f32]], out: &mut [f32]) -> Result<CommReport> {
        self.inner.allreduce_mean(inputs, out)
    }

    fn sync_round(
        &mut self,
        xs: &[&[f32]],
        accs: Option<&[&[f32]]>,
        avg_x: &mut [f32],
        avg_acc: Option<&mut [f32]>,
    ) -> Result<CommReport> {
        self.inner.sync_round(xs, accs, avg_x, avg_acc)
    }

    fn sync_round_partial(
        &mut self,
        xs: &[&[f32]],
        accs: Option<&[&[f32]]>,
        arrivals: &[f64],
        avg_x: &mut [f32],
        avg_acc: Option<&mut [f32]>,
    ) -> Result<PartialRound> {
        if arrivals.len() != xs.len() {
            return Err(Error::Protocol(format!(
                "sync_round_partial: {} arrivals for {} workers",
                arrivals.len(),
                xs.len()
            )));
        }
        if let Some(accs) = accs {
            if accs.len() != xs.len() {
                return Err(Error::Protocol(format!(
                    "sync_round_partial: {} accumulators for {} workers",
                    accs.len(),
                    xs.len()
                )));
            }
        }
        let (participants, dropped, close_s) = self.policy.select(arrivals)?;
        let xs_p: Vec<&[f32]> = participants.iter().map(|&i| xs[i]).collect();
        let accs_p: Option<Vec<&[f32]>> =
            accs.map(|a| participants.iter().map(|&i| a[i]).collect());
        let report = self.inner.sync_round(&xs_p, accs_p.as_deref(), avg_x, avg_acc)?;
        Ok(PartialRound { participants, dropped, close_s, report })
    }
}

fn check_acc_pairing(accs_some: bool, avg_some: bool) -> Result<()> {
    if accs_some != avg_some {
        return Err(Error::Protocol(
            "sync_round: accs and avg_acc must both be present or both absent".into(),
        ));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// ChannelCollective — the in-process lockstep baseline.
// ---------------------------------------------------------------------------

/// The current in-process mpsc lockstep: exact f32 means in the leader's
/// address space, zero modeled cost. Bitwise-identical to the seed trainer
/// (it runs the same [`math::mean_into`] the trainer inlined before).
///
/// With `comm.shards = k` the averaging runs per shard range
/// ([`ShardPlan`]) — the dataflow the k shard servers execute in
/// parallel — which is bitwise-identical to the dense mean (per-coordinate
/// kernels; pinned in `comm::shard`).
///
/// With `comm.pipeline = depth ≥ 2` the per-shard means additionally fan
/// out over a scoped-thread [`Executor`] ([`mean_into_sharded_exec`]) —
/// shard *i* reduces while shard *i+1* is still being staged. Still
/// bitwise-identical: the per-range kernels and their internal operation
/// order are untouched, only the shard schedule overlaps.
pub struct ChannelCollective {
    n: usize,
    d: usize,
    plan: ShardPlan,
    exec: Executor,
    pipeline: usize,
}

impl ChannelCollective {
    /// `n` workers, model dimension `d`, single leader (the unsharded,
    /// seed-bitwise transport).
    pub fn new(n: usize, d: usize) -> Self {
        ChannelCollective::sharded(n, d, 1)
    }

    /// `n` workers, model dimension `d`, `shards` leader shards
    /// (`comm.shards`; range partition of `[0, d)`).
    pub fn sharded(n: usize, d: usize, shards: usize) -> Self {
        ChannelCollective::pipelined(n, d, shards, 0)
    }

    /// [`ChannelCollective::sharded`] with a `comm.pipeline` depth:
    /// `depth ≥ 2` reduces up to `depth` shard ranges concurrently
    /// (capped at the shard count); `0` and `1` are the serial schedule.
    pub fn pipelined(n: usize, d: usize, shards: usize, depth: usize) -> Self {
        let plan = ShardPlan::new(d, shards);
        let exec = if depth >= 2 && plan.shards() > 1 {
            Executor::threads(depth.min(plan.shards()))
        } else {
            Executor::serial()
        };
        ChannelCollective { n, d, plan, exec, pipeline: depth }
    }

    /// Model dimension.
    pub fn d(&self) -> usize {
        self.d
    }

    /// The leader-shard range partition.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// The executor the per-shard reduction stage fans over (serial
    /// unless a `comm.pipeline` depth ≥ 2 was configured).
    pub fn exec(&self) -> &Executor {
        &self.exec
    }

    /// The configured `comm.pipeline` depth (0 = off).
    pub fn pipeline(&self) -> usize {
        self.pipeline
    }
}

impl Collective for ChannelCollective {
    fn n(&self) -> usize {
        self.n
    }

    fn label(&self) -> String {
        let mut l = if self.plan.is_dense() {
            "channel".to_string()
        } else {
            format!("channel(shards={})", self.plan.shards())
        };
        if self.pipeline > 0 {
            l.push_str(&format!("+pipe({})", self.pipeline));
        }
        l
    }

    fn gather_grads(&mut self, grads: &mut [Vec<f32>]) -> Result<CommReport> {
        for (w, g) in grads.iter().enumerate() {
            if g.len() != self.d {
                return Err(Error::Protocol(format!(
                    "gather_grads: worker {w} gradient len {} != d {}",
                    g.len(),
                    self.d
                )));
            }
        }
        Ok(CommReport { rounds: 1, ..CommReport::zero() })
    }

    fn allreduce_mean(&mut self, inputs: &[&[f32]], out: &mut [f32]) -> Result<CommReport> {
        mean_into_sharded_exec(&self.plan, &self.exec, inputs, out);
        Ok(CommReport {
            rounds: 1,
            drift_sq: mean_sq_dist(inputs, out),
            ..CommReport::zero()
        })
    }

    fn sync_round(
        &mut self,
        xs: &[&[f32]],
        accs: Option<&[&[f32]]>,
        avg_x: &mut [f32],
        avg_acc: Option<&mut [f32]>,
    ) -> Result<CommReport> {
        check_acc_pairing(accs.is_some(), avg_acc.is_some())?;
        mean_into_sharded_exec(&self.plan, &self.exec, xs, avg_x);
        if let (Some(accs), Some(avg_acc)) = (accs, avg_acc) {
            mean_into_sharded_exec(&self.plan, &self.exec, accs, avg_acc);
        }
        Ok(CommReport {
            rounds: 1,
            drift_sq: mean_sq_dist(xs, avg_x),
            ..CommReport::zero()
        })
    }
}

// ---------------------------------------------------------------------------
// SimulatedCollective — α–β cost model charged per op.
// ---------------------------------------------------------------------------

/// The cost constants a [`SimulatedCollective`] charges per round: the α–β
/// network model, the paper-scale payload (0.83B-param Big LSTM, so the
/// PPL-vs-time curves reproduce Fig. 3a), and the MXNet overlap discounts
/// from [`Calibration`]. Traffic accounting, in contrast, always uses the
/// real `4·d` bytes this run shipped.
#[derive(Clone, Debug)]
pub struct SimCost {
    /// The α–β network model charging each round.
    pub net: NetModel,
    /// Bytes of one synchronized vector at the modeled scale.
    pub model_bytes: u64,
    /// Overlap discount γ₁ for per-iteration gradient sync.
    pub overlap: f64,
    /// Overlap discount γ₂ for periodic bulk state sync.
    pub periodic_overlap: f64,
}

impl SimCost {
    /// Assemble from the experiment's network section and the virtual-time
    /// calibration (DESIGN.md §3).
    pub fn from_config(cfg: &ExperimentConfig, calib: &Calibration) -> Self {
        SimCost {
            net: NetModel::from_config(&cfg.net).with_shards(cfg.comm.shards),
            model_bytes: calib.vector_bytes(),
            overlap: calib.overlap,
            periodic_overlap: calib.periodic_overlap,
        }
    }
}

/// Decorates the lockstep data ops with per-op α–β charges — the virtual
/// clock and byte accounting live here, not in `Trainer::run`.
pub struct SimulatedCollective {
    inner: ChannelCollective,
    cost: SimCost,
}

impl SimulatedCollective {
    /// Wrap the lockstep data ops with the given per-round cost model.
    pub fn new(inner: ChannelCollective, cost: SimCost) -> Self {
        SimulatedCollective { inner, cost }
    }

    /// One sync round of `vectors` model-sized vectors among `n` round
    /// participants (== the cluster size except under partial-participation
    /// rounds or after crashes); `periodic` selects the bulk-sync overlap
    /// discount (local algorithms) vs the per-iteration gradient-sync
    /// discount. The straggler observation is the raw (non-discounted)
    /// incast spread at the modeled payload — overlap hides time from the
    /// critical path, not the worker skew.
    fn charge(&self, n: usize, vectors: u64, periodic: bool) -> CommReport {
        let gamma = if periodic { self.cost.periodic_overlap } else { self.cost.overlap };
        // The time model divides the incast by `shards` internally; the
        // straggler observation is likewise the per-shard-server spread.
        let time_s = (1.0 - gamma) * self.cost.net.sync_time(n, self.cost.model_bytes, vectors);
        // Per-shard byte accounting: each shard server books the traffic
        // of its own index range. The traffic formulas are linear in the
        // payload, so the sum over the range partition equals the dense
        // total exactly (u64 arithmetic, no rounding).
        let bytes = self
            .inner
            .plan()
            .ranges()
            .map(|r| self.cost.net.sync_traffic_bytes(n, 4 * r.len() as u64, vectors))
            .sum();
        let straggler_s = self.cost.net.straggler_spread_s(n, self.cost.model_bytes * vectors);
        CommReport { bytes, time_s, rounds: 1, drift_sq: 0.0, straggler_s }
    }

    fn topology_name(&self) -> &'static str {
        match self.cost.net.topology {
            Topology::ParameterServer => "ps",
            Topology::RingAllReduce => "allreduce",
            Topology::TreeAllReduce => "tree",
        }
    }
}

impl Collective for SimulatedCollective {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn label(&self) -> String {
        if self.inner.plan().is_dense() {
            format!("simulated({})", self.topology_name())
        } else {
            format!(
                "simulated({}, shards={})",
                self.topology_name(),
                self.inner.plan().shards()
            )
        }
    }

    fn gather_grads(&mut self, grads: &mut [Vec<f32>]) -> Result<CommReport> {
        let n = grads.len();
        self.inner.gather_grads(grads)?;
        Ok(self.charge(n, 1, false))
    }

    fn allreduce_mean(&mut self, inputs: &[&[f32]], out: &mut [f32]) -> Result<CommReport> {
        let inner = self.inner.allreduce_mean(inputs, out)?;
        let mut rep = self.charge(inputs.len(), 1, true);
        rep.drift_sq = inner.drift_sq;
        Ok(rep)
    }

    fn sync_round(
        &mut self,
        xs: &[&[f32]],
        accs: Option<&[&[f32]]>,
        avg_x: &mut [f32],
        avg_acc: Option<&mut [f32]>,
    ) -> Result<CommReport> {
        let vectors = 1 + accs.is_some() as u64;
        let inner = self.inner.sync_round(xs, accs, avg_x, avg_acc)?;
        let mut rep = self.charge(xs.len(), vectors, true);
        rep.drift_sq = inner.drift_sq;
        Ok(rep)
    }
}

// ---------------------------------------------------------------------------
// CompressedCollective — QSGD / top-k wire compression with exact bytes.
// ---------------------------------------------------------------------------

/// Per-stream compressor. Top-k carries error-feedback residuals, which
/// are per-(worker, vector-kind) state — every logical stream gets its own
/// sparsifier so residual mass never leaks across streams. Both lossy
/// codecs keep a reused message scratch so steady-state roundtrips never
/// touch the allocator (DESIGN.md §7). Bf16 is stateless: the payload is
/// rounded through bf16 in place ([`crate::util::half`]) and billed at
/// exactly 2 bytes per element.
enum Codec {
    Qsgd { q: QsgdQuantizer, seed: u64, uses: Vec<u64>, enc: QsgdEncoded },
    TopK { keep: f64, streams: Vec<Option<TopKSparsifier>>, msg: SparseGrad },
    Bf16,
}

impl Codec {
    /// Encode → count exact wire bytes → decode back into `v` in place.
    fn roundtrip(&mut self, stream: usize, v: &mut [f32]) -> u64 {
        match self {
            Codec::Qsgd { q, seed, uses, enc } => {
                // Fresh RNG per (stream, use), derived — not sequential —
                // so a worker process encoding the same stream derives the
                // identical draws without shared state (the wire codec,
                // [`crate::comm::wire::qsgd_stream_rng`], is keyed the
                // same way; DESIGN.md §4).
                if uses.len() <= stream {
                    uses.resize(stream + 1, 0);
                }
                let mut rng =
                    crate::comm::wire::qsgd_stream_rng(*seed, stream as u64, uses[stream]);
                uses[stream] += 1;
                q.encode_to(v, &mut rng, enc);
                q.decode(enc, v);
                q.wire_bytes(v.len())
            }
            Codec::TopK { keep, streams, msg } => {
                if stream >= streams.len() {
                    streams.resize_with(stream + 1, || None);
                }
                let sp = streams[stream]
                    .get_or_insert_with(|| TopKSparsifier::new(v.len(), *keep));
                sp.encode_into(v, msg);
                v.fill(0.0);
                for (&i, &val) in msg.idx.iter().zip(&msg.val) {
                    v[i as usize] = val;
                }
                msg.wire_bytes()
            }
            Codec::Bf16 => {
                crate::util::half::quantize_assign(v);
                crate::util::half::wire_bytes(v.len())
            }
        }
    }

    /// Bytes per element of the dense model pull back to the workers: the
    /// bf16 wire halves the down leg too; the sparse/quantized codecs pull
    /// the dense f32 model (the leader owns `x`).
    fn pull_bytes_per_elem(&self) -> u64 {
        match self {
            Codec::Bf16 => 2,
            Codec::Qsgd { .. } | Codec::TopK { .. } => 4,
        }
    }

    fn label(&self) -> String {
        match self {
            Codec::Qsgd { q, .. } => format!("qsgd(s={})", q.levels()),
            Codec::TopK { keep, .. } => format!("topk({keep})"),
            Codec::Bf16 => "bf16".into(),
        }
    }
}

/// Wire-compression decorator over the lockstep data ops.
///
/// * **Gradient gather** (sync algorithms): each worker's gradient goes
///   through `decode(encode(·))` and its exact encoded size is billed; the
///   model pull back to the workers stays dense (the leader owns `x`), so
///   the round bills `Σ enc(g_i) + n·4d` bytes.
/// * **Sync round** (local algorithms): workers push *deltas against the
///   last synchronized state* (the quantity compressed local-SGD actually
///   ships — raw parameters have no reason to be small); the leader
///   averages the decoded deltas, compresses the average once for the
///   broadcast down, and installs `base + decode(enc(mean Δ))` everywhere,
///   so all replicas stay identical. Bills `Σ enc(Δ_i) + n·enc(mean Δ)`
///   per synchronized vector. Averaged denominators are clamped at 0 after
///   the lossy roundtrip (the `t'·ε²` placeholder keeps the installed
///   denominator strictly positive, so training stays finite).
pub struct CompressedCollective {
    inner: ChannelCollective,
    codec: Codec,
    net: NetModel,
    /// Last synchronized parameters (delta-compression base; zeros before
    /// the first round).
    base_x: Vec<f32>,
    /// Last synchronized denominators.
    base_acc: Vec<f32>,
    /// Pooled per-worker delta/staging buffers, reused every round so the
    /// steady-state sync round never allocates (DESIGN.md §7).
    delta_bufs: Vec<Vec<f32>>,
    /// Pooled mean-delta buffer for the down leg.
    mean_buf: Vec<f32>,
}

// Stream-id layout: one error-feedback stream per (worker, purpose), so
// residual mass never leaks between the gradient path, the two sync-round
// vector families, and standalone allreduces. Free functions of the
// cluster size `n` so `compressed_average` can hold disjoint field
// borrows while computing stream ids. `pub(crate)`: the networked
// transport (`comm::net`) encodes the same logical streams on the real
// wire and must key its per-stream RNGs identically (DESIGN.md §4).
pub(crate) fn up_stream(n: usize, family: StreamFamily, w: usize) -> usize {
    match family {
        StreamFamily::SyncX => n + w,
        StreamFamily::SyncAcc => 2 * n + w,
        StreamFamily::Raw => 3 * n + 2 + w,
    }
}
pub(crate) fn down_stream(n: usize, family: StreamFamily) -> usize {
    match family {
        StreamFamily::SyncX => 3 * n,
        StreamFamily::SyncAcc => 3 * n + 1,
        StreamFamily::Raw => 4 * n + 2,
    }
}
/// The gradient path's per-worker stream id (shared with `comm::net`).
pub(crate) fn grad_stream(w: usize) -> usize {
    w
}

impl CompressedCollective {
    /// QSGD stochastic quantization with `s` levels.
    pub fn qsgd(inner: ChannelCollective, net: NetModel, s: u8, seed: u64) -> Self {
        // Whole-vector norms don't commute with a range partition
        // (CommConfig::validate rejects the combination from config).
        debug_assert!(inner.plan().is_dense(), "qsgd does not compose with comm.shards > 1");
        let d = inner.d();
        CompressedCollective {
            inner,
            codec: Codec::Qsgd {
                q: QsgdQuantizer::new(s),
                seed,
                uses: Vec::new(),
                enc: QsgdEncoded { norm: 0.0, levels: Vec::new(), s },
            },
            net,
            base_x: vec![0.0; d],
            base_acc: vec![0.0; d],
            delta_bufs: Vec::new(),
            mean_buf: Vec::new(),
        }
    }

    /// The bf16 wire format (`precision.wire = "bf16"`; DESIGN.md §8):
    /// every payload is rounded through bf16 (round-to-nearest-even) and
    /// billed at 2 bytes/element — exactly half the dense f32 wire, on the
    /// up and down legs alike. Sync rounds compose with the same delta
    /// coding the lossy codecs use (the shipped quantity is `Δ` against
    /// the last synchronized state, where bf16's relative error does the
    /// least damage).
    pub fn bf16(inner: ChannelCollective, net: NetModel) -> Self {
        let d = inner.d();
        CompressedCollective {
            inner,
            codec: Codec::Bf16,
            net,
            base_x: vec![0.0; d],
            base_acc: vec![0.0; d],
            delta_bufs: Vec::new(),
            mean_buf: Vec::new(),
        }
    }

    /// Magnitude top-k with error feedback, keeping fraction `keep`.
    pub fn topk(inner: ChannelCollective, net: NetModel, keep: f64) -> Self {
        // Global magnitude selection doesn't commute with a range
        // partition (CommConfig::validate rejects the combination).
        debug_assert!(inner.plan().is_dense(), "topk does not compose with comm.shards > 1");
        let d = inner.d();
        CompressedCollective {
            inner,
            codec: Codec::TopK {
                keep,
                streams: Vec::new(),
                msg: SparseGrad { d, idx: Vec::new(), val: Vec::new() },
            },
            net,
            base_x: vec![0.0; d],
            base_acc: vec![0.0; d],
            delta_bufs: Vec::new(),
            mean_buf: Vec::new(),
        }
    }

    /// Compress one up/down vector family: per-worker payloads (deltas
    /// against the family's base for the sync families, raw values for
    /// `Raw`) staged in the pooled buffers, lockstep mean (the same
    /// cache-blocked kernel the plain channel mean runs),
    /// down-compressed average written into `out`; returns the exact wire
    /// bytes billed. Steady state performs zero heap allocations: the
    /// staging, mean and codec scratch buffers are all reused.
    fn compressed_average(
        &mut self,
        sources: &[&[f32]],
        family: StreamFamily,
        out: &mut [f32],
    ) -> Result<u64> {
        let CompressedCollective { inner, codec, base_x, base_acc, delta_bufs, mean_buf, .. } =
            self;
        let n = inner.n();
        let d = inner.d();
        let mut bytes = 0u64;
        if delta_bufs.len() < sources.len() {
            delta_bufs.resize_with(sources.len(), Vec::new);
        }
        for (w, src) in sources.iter().enumerate() {
            if src.len() != d {
                return Err(Error::Protocol(format!(
                    "compressed_average: worker {w} vector len {} != d {d}",
                    src.len()
                )));
            }
            let buf = &mut delta_bufs[w];
            buf.resize(d, 0.0);
            match family {
                StreamFamily::SyncX => kernels::delta_encode(src, base_x, buf),
                StreamFamily::SyncAcc => kernels::delta_encode(src, base_acc, buf),
                StreamFamily::Raw => buf.copy_from_slice(src),
            }
            // With leader shards, the up leg is one message per shard
            // server: the elementwise codecs (f32/bf16) encode each range
            // exactly as they would the dense vector, and the per-range
            // byte bills sum to the dense total exactly (enc_len is
            // linear). The lossy codecs only ever see the dense plan.
            let plan = inner.plan();
            if plan.is_dense() {
                bytes += codec.roundtrip(up_stream(n, family, w), buf);
            } else {
                for r in plan.ranges().filter(|r| !r.is_empty()) {
                    bytes += codec.roundtrip(up_stream(n, family, w), &mut buf[r]);
                }
            }
        }
        mean_buf.resize(d, 0.0);
        // The reduction stage. With a pipelined sharded plan the
        // per-range means fan over the inner executor (bitwise ≡ the
        // dense mean, pinned in comm::shard); otherwise the dense
        // alloc-free kernel. (The lossy codecs only ever see the dense
        // plan, so only f32/bf16 wires can take the fanned branch.)
        use crate::coordinator::executor::Parallelism;
        if !inner.plan().is_dense()
            && !matches!(inner.exec().parallelism(), Parallelism::Serial)
        {
            let refs: Vec<&[f32]> =
                delta_bufs[..sources.len()].iter().map(|v| v.as_slice()).collect();
            mean_into_sharded_exec(inner.plan(), inner.exec(), &refs, mean_buf);
        } else {
            kernels::mean_into(&delta_bufs[..sources.len()], mean_buf);
        }
        // Down leg: each shard server broadcasts its averaged range to all
        // n workers (again summing to exactly the dense bill).
        let plan = inner.plan();
        if plan.is_dense() {
            bytes += n as u64 * codec.roundtrip(down_stream(n, family), mean_buf);
        } else {
            for r in plan.ranges().filter(|r| !r.is_empty()) {
                bytes += n as u64 * codec.roundtrip(down_stream(n, family), &mut mean_buf[r]);
            }
        }
        match family {
            StreamFamily::SyncX => {
                kernels::delta_decode(base_x, mean_buf, out);
                base_x.copy_from_slice(out);
            }
            StreamFamily::SyncAcc => {
                // Clamp: the lossy roundtrip can push a denominator
                // coordinate below zero; project back onto the feasible
                // cone so sqrt(b² + t'·ε²) stays real.
                kernels::delta_decode_clamped(base_acc, mean_buf, out);
                base_acc.copy_from_slice(out);
            }
            StreamFamily::Raw => {
                // Standalone allreduce: no delta base involved — the
                // sync-round state (bases, sync streams) is untouched.
                out.copy_from_slice(mean_buf);
            }
        }
        Ok(bytes)
    }
}

/// Which compression stream family a vector exchange belongs to. The sync
/// families delta-code against (and advance) the last synchronized state;
/// `Raw` is for standalone allreduces and must never touch that state.
#[derive(Clone, Copy, PartialEq, Eq)]
pub(crate) enum StreamFamily {
    /// Parameter vectors of a sync round.
    SyncX,
    /// Accumulated denominators of a sync round.
    SyncAcc,
    /// Standalone allreduce payloads (no delta base).
    Raw,
}

impl Collective for CompressedCollective {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn label(&self) -> String {
        if self.inner.plan().is_dense() {
            self.codec.label()
        } else {
            format!("{}(shards={})", self.codec.label(), self.inner.plan().shards())
        }
    }

    fn broadcast(&mut self, x: &mut [f32]) -> Result<CommReport> {
        // The bf16 wire rounds the broadcast model onto the bf16 grid —
        // the workers receive exactly what bf16 wire bytes can carry, the
        // same image a networked bf16 worker decodes (DESIGN.md §4). Still
        // billed free here: the pull leg is accounted at 2 bytes/elem by
        // the round op, as for every transport. The lossy codecs leave the
        // broadcast dense (the leader owns `x`; its pull is billed at
        // 4 bytes/elem).
        if matches!(self.codec, Codec::Bf16) && self.inner.n() > 1 {
            crate::util::half::quantize_assign(x);
        }
        Ok(CommReport::zero())
    }

    fn gather_grads(&mut self, grads: &mut [Vec<f32>]) -> Result<CommReport> {
        let n = self.inner.n();
        if n <= 1 {
            // Nothing crosses a wire with one worker; keep data exact.
            return self.inner.gather_grads(grads);
        }
        let mut bytes = 0u64;
        let plan = self.inner.plan().clone();
        for (w, g) in grads.iter_mut().enumerate() {
            if plan.is_dense() {
                bytes += self.codec.roundtrip(grad_stream(w), g);
            } else {
                for r in plan.ranges().filter(|r| !r.is_empty()) {
                    bytes += self.codec.roundtrip(grad_stream(w), &mut g[r]);
                }
            }
        }
        self.inner.gather_grads(grads)?;
        // Dense model pull back to every worker (2 bytes/elem on the bf16
        // wire, 4 otherwise).
        bytes += n as u64 * self.codec.pull_bytes_per_elem() * self.inner.d() as u64;
        Ok(CommReport {
            bytes,
            time_s: self.net.bytes_time(n, bytes),
            rounds: 1,
            drift_sq: 0.0,
            straggler_s: self.net.straggler_spread_s(n, bytes / (2 * n as u64)),
        })
    }

    fn allreduce_mean(&mut self, inputs: &[&[f32]], out: &mut [f32]) -> Result<CommReport> {
        let n = self.inner.n();
        if n <= 1 {
            return self.inner.allreduce_mean(inputs, out);
        }
        let bytes = self.compressed_average(inputs, StreamFamily::Raw, out)?;
        Ok(CommReport {
            bytes,
            time_s: self.net.bytes_time(n, bytes),
            rounds: 1,
            drift_sq: mean_sq_dist(inputs, out),
            straggler_s: self.net.straggler_spread_s(n, bytes / (2 * n as u64)),
        })
    }

    fn sync_round(
        &mut self,
        xs: &[&[f32]],
        accs: Option<&[&[f32]]>,
        avg_x: &mut [f32],
        avg_acc: Option<&mut [f32]>,
    ) -> Result<CommReport> {
        check_acc_pairing(accs.is_some(), avg_acc.is_some())?;
        let n = self.inner.n();
        if n <= 1 {
            return self.inner.sync_round(xs, accs, avg_x, avg_acc);
        }
        let mut bytes = self.compressed_average(xs, StreamFamily::SyncX, avg_x)?;
        // The realized replica drift, against the (decoded) installed
        // average — what an adaptive policy actually wants to bound.
        let drift_sq = mean_sq_dist(xs, avg_x);
        if let (Some(accs), Some(avg_acc)) = (accs, avg_acc) {
            bytes += self.compressed_average(accs, StreamFamily::SyncAcc, avg_acc)?;
        }
        Ok(CommReport {
            bytes,
            time_s: self.net.bytes_time(n, bytes),
            rounds: 1,
            drift_sq,
            // First-order per-worker payload: total split over up+down legs.
            straggler_s: self.net.straggler_spread_s(n, bytes / (2 * n as u64)),
        })
    }
}

// ---------------------------------------------------------------------------
// Config-driven construction.
// ---------------------------------------------------------------------------

/// Build the collective the `[comm]` config section asks for — the single
/// entry point the trainer (and benches) use, so "local AdaAlter over a
/// compressed ring all-reduce" is a config choice, not a rewrite.
pub fn build_collective(
    cfg: &ExperimentConfig,
    calib: &Calibration,
    d: usize,
) -> Result<Box<dyn Collective>> {
    // Re-run the `[comm]`/`[precision]` rules here: TOML-loaded configs
    // were already validated, but programmatically-built ones (benches,
    // tests, library users) reach this gate directly. Single rule copy:
    // CommConfig / PrecisionConfig.
    cfg.comm.validate()?;
    cfg.precision.validate()?;
    cfg.precision.validate_with_comm(&cfg.comm)?;
    if cfg.comm.networked() && (cfg.comm.compression != "none" || cfg.precision.wire_bf16()) {
        // Over real sockets the lossy codecs live in the leader's
        // [`crate::comm::net::WireCollective`] (the payloads *are* the
        // socket frames); the trainer builds it directly.
        return Err(Error::Config(format!(
            "comm.transport = {:?} with a lossy wire codec is driven by the \
             trainer's networked path, not build_collective",
            cfg.comm.transport
        )));
    }
    if cfg.comm.shards > 1 && cfg.net.topology != "ps" {
        // Cross-section rule, re-run here for programmatically-built
        // configs (ExperimentConfig::validate owns the TOML path).
        return Err(Error::Config(format!(
            "comm.shards > 1 shards the parameter server; net.topology must \
             be \"ps\", got {:?}",
            cfg.net.topology
        )));
    }
    let n = cfg.train.workers;
    let base = ChannelCollective::pipelined(n, d, cfg.comm.shards, cfg.comm.pipeline);
    let coll: Box<dyn Collective> = match cfg.comm.compression.as_str() {
        "none" => match cfg.comm.transport.as_str() {
            // The bf16 wire rides the compressed-collective machinery
            // (delta coding + exact byte accounting) over the lockstep
            // channel.
            "channel" if cfg.precision.wire_bf16() => Box::new(CompressedCollective::bf16(
                base,
                NetModel::from_config(&cfg.net).with_shards(cfg.comm.shards),
            )),
            "channel" => Box::new(base),
            _ => Box::new(SimulatedCollective::new(
                base,
                SimCost::from_config(cfg, calib),
            )),
        },
        "qsgd" => Box::new(CompressedCollective::qsgd(
            base,
            NetModel::from_config(&cfg.net),
            cfg.comm.qsgd_levels,
            cfg.train.seed,
        )),
        "topk" => Box::new(CompressedCollective::topk(
            base,
            NetModel::from_config(&cfg.net),
            cfg.comm.topk_keep,
        )),
        other => unreachable!("CommConfig::validate rejects compression {other:?}"),
    };
    // A `[faults]` participation policy decorates whatever transport was
    // selected — quorum rounds are a config choice, not a rewrite.
    match Participation::from_config(&cfg.faults) {
        Some(policy) => Ok(Box::new(PartialCollective::new(coll, policy))),
        None => Ok(coll),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn refs(v: &[Vec<f32>]) -> Vec<&[f32]> {
        v.iter().map(|x| x.as_slice()).collect()
    }

    #[test]
    fn channel_mean_matches_math() {
        let mut c = ChannelCollective::new(2, 3);
        let a = vec![vec![1.0f32, 2.0, 3.0], vec![3.0, 2.0, 1.0]];
        let mut out = vec![0.0f32; 3];
        let rep = c.allreduce_mean(&refs(&a), &mut out).unwrap();
        assert_eq!(out, vec![2.0, 2.0, 2.0]);
        assert_eq!((rep.bytes, rep.rounds), (0, 1));
        assert_eq!(rep.time_s, 0.0);
    }

    #[test]
    fn channel_sync_round_averages_both_vectors() {
        let mut c = ChannelCollective::new(2, 2);
        let xs = vec![vec![0.0f32, 4.0], vec![2.0, 0.0]];
        let accs = vec![vec![1.0f32, 1.0], vec![3.0, 5.0]];
        let mut avg_x = vec![0.0f32; 2];
        let mut avg_acc = vec![0.0f32; 2];
        c.sync_round(&refs(&xs), Some(&refs(&accs)), &mut avg_x, Some(&mut avg_acc))
            .unwrap();
        assert_eq!(avg_x, vec![1.0, 2.0]);
        assert_eq!(avg_acc, vec![2.0, 3.0]);
        // Mismatched acc pairing is a protocol error.
        assert!(c.sync_round(&refs(&xs), None, &mut avg_x, Some(&mut avg_acc)).is_err());
    }

    #[test]
    fn simulated_charges_match_netmodel() {
        let cfg = ExperimentConfig::default();
        let calib = Calibration::paper_v100();
        let d = 128;
        let n = cfg.train.workers;
        let cost = SimCost::from_config(&cfg, &calib);
        let net = cost.net.clone();
        let mut sim = SimulatedCollective::new(ChannelCollective::new(n, d), cost);

        let mut grads: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0f32; d]).collect();
        let rep = sim.gather_grads(&mut grads).unwrap();
        assert_eq!(rep.bytes, net.sync_traffic_bytes(n, 4 * d as u64, 1));
        let want_t = (1.0 - calib.overlap) * net.sync_time(n, calib.vector_bytes(), 1);
        assert!((rep.time_s - want_t).abs() < 1e-12);
        assert_eq!(rep.rounds, 1);
        // Data untouched.
        assert!(grads.iter().all(|g| g.iter().all(|&v| v == 1.0)));

        let xs: Vec<Vec<f32>> = (0..n).map(|_| vec![2.0f32; d]).collect();
        let accs = xs.clone();
        let mut ax = vec![0.0f32; d];
        let mut aa = vec![0.0f32; d];
        let rep = sim
            .sync_round(&refs(&xs), Some(&refs(&accs)), &mut ax, Some(&mut aa))
            .unwrap();
        assert_eq!(rep.bytes, net.sync_traffic_bytes(n, 4 * d as u64, 2));
        let want_t =
            (1.0 - calib.periodic_overlap) * net.sync_time(n, calib.vector_bytes(), 2);
        assert!((rep.time_s - want_t).abs() < 1e-12);
    }

    #[test]
    fn sync_round_reports_drift_and_straggler_observations() {
        // Channel: drift is the exact mean squared distance from the mean.
        let mut c = ChannelCollective::new(2, 2);
        let xs = vec![vec![0.0f32, 0.0], vec![2.0, 0.0]];
        let mut avg = vec![0.0f32; 2];
        let rep = c.sync_round(&refs(&xs), None, &mut avg, None).unwrap();
        // mean = [1, 0]; each worker at squared distance 1 → mean 1.
        assert!((rep.drift_sq - 1.0).abs() < 1e-12, "{}", rep.drift_sq);
        assert_eq!(rep.straggler_s, 0.0);

        // Identical replicas drift zero.
        let same = vec![vec![3.0f32, 4.0], vec![3.0, 4.0]];
        let rep = c.sync_round(&refs(&same), None, &mut avg, None).unwrap();
        assert_eq!(rep.drift_sq, 0.0);

        // Simulated: inner drift propagates, PS straggler spread is the
        // netmodel's (n−1)·B/β at the modeled payload.
        let cfg = ExperimentConfig::default();
        let calib = Calibration::paper_v100();
        let n = cfg.train.workers;
        let cost = SimCost::from_config(&cfg, &calib);
        let net = cost.net.clone();
        let model_bytes = cost.model_bytes;
        let mut sim = SimulatedCollective::new(ChannelCollective::new(n, 2), cost);
        let xs: Vec<Vec<f32>> = (0..n).map(|w| vec![w as f32, 0.0]).collect();
        let mut avg = vec![0.0f32; 2];
        let rep = sim.sync_round(&refs(&xs), None, &mut avg, None).unwrap();
        assert!(rep.drift_sq > 0.0);
        let want = net.straggler_spread_s(n, model_bytes);
        assert!((rep.straggler_s - want).abs() < 1e-15);

        // merge keeps the worst observation and sums the costs.
        let a = CommReport { drift_sq: 1.0, straggler_s: 0.25, ..CommReport::zero() };
        let b = CommReport { drift_sq: 4.0, straggler_s: 0.125, ..CommReport::zero() };
        let m = a.merge(b);
        assert_eq!((m.drift_sq, m.straggler_s), (4.0, 0.25));
    }

    #[test]
    fn qsgd_gather_counts_exact_bytes() {
        let (n, d) = (4usize, 256usize);
        let net = NetModel::from_config(&crate::config::NetConfig::default());
        let mut c = CompressedCollective::qsgd(ChannelCollective::new(n, d), net, 15, 7);
        let mut grads: Vec<Vec<f32>> =
            (0..n).map(|w| (0..d).map(|i| ((i + w) as f32 * 0.1).sin()).collect()).collect();
        let rep = c.gather_grads(&mut grads).unwrap();
        let q = QsgdQuantizer::new(15);
        let want = n as u64 * q.wire_bytes(d) + n as u64 * 4 * d as u64;
        assert_eq!(rep.bytes, want);
        assert!(rep.time_s > 0.0);
        assert!(grads.iter().all(|g| g.iter().all(|v| v.is_finite())));
    }

    #[test]
    fn bf16_wire_halves_bytes_exactly() {
        // The acceptance pin: against the dense f32 accounting of the
        // simulated transport (PS: 2·n·payload per round), the bf16 wire
        // bills EXACTLY half — on the paired sync round and on the
        // gradient gather alike.
        let (n, d) = (4usize, 256usize);
        let net = NetModel::from_config(&crate::config::NetConfig::default());
        let dense_round = net.sync_traffic_bytes(n, 4 * d as u64, 2);
        let dense_gather = net.sync_traffic_bytes(n, 4 * d as u64, 1);
        let mut c = CompressedCollective::bf16(ChannelCollective::new(n, d), net);
        assert_eq!(c.label(), "bf16");

        let xs: Vec<Vec<f32>> =
            (0..n).map(|w| (0..d).map(|i| ((i + w) as f32 * 0.1).sin()).collect()).collect();
        let accs: Vec<Vec<f32>> = (0..n).map(|_| vec![1.5f32; d]).collect();
        let mut avg_x = vec![0.0f32; d];
        let mut avg_acc = vec![0.0f32; d];
        let rep = c
            .sync_round(&refs(&xs), Some(&refs(&accs)), &mut avg_x, Some(&mut avg_acc))
            .unwrap();
        assert_eq!(rep.bytes * 2, dense_round, "sync round not exactly half");
        assert!(rep.time_s > 0.0);

        let mut grads: Vec<Vec<f32>> =
            (0..n).map(|w| (0..d).map(|i| ((i * 3 + w) as f32 * 0.07).cos()).collect()).collect();
        let rep = c.gather_grads(&mut grads).unwrap();
        assert_eq!(rep.bytes * 2, dense_gather, "gather not exactly half");
        // The gathered gradients are the bf16 images of the originals.
        for g in &grads {
            for &v in g {
                assert_eq!(v.to_bits(), crate::util::half::round_f32(v).to_bits());
            }
        }
    }

    #[test]
    fn bf16_sync_round_is_accurate_and_lands_on_grid() {
        let (n, d) = (3usize, 64usize);
        let net = NetModel::from_config(&crate::config::NetConfig::default());
        let mut c = CompressedCollective::bf16(ChannelCollective::new(n, d), net);
        let xs: Vec<Vec<f32>> =
            (0..n).map(|w| (0..d).map(|i| (i as f32 + w as f32) * 0.01).collect()).collect();
        let accs: Vec<Vec<f32>> = (0..n).map(|_| vec![1.5f32; d]).collect();
        let mut avg_x = vec![0.0f32; d];
        let mut avg_acc = vec![0.0f32; d];
        c.sync_round(&refs(&xs), Some(&refs(&accs)), &mut avg_x, Some(&mut avg_acc))
            .unwrap();
        // bf16 keeps 8 mantissa bits: the installed average is within ~1%
        // of the exact mean, never negative on the denominator side.
        let mut want = vec![0.0f32; d];
        math::mean_into(&refs(&xs), &mut want);
        for i in 0..d {
            assert!((avg_x[i] - want[i]).abs() <= 0.01 * want[i].abs().max(0.01), "i={i}");
        }
        assert!(avg_acc.iter().all(|&v| v >= 0.0));
        // First round: base was 0 (a grid point), so the installed state
        // is itself on the bf16 grid — the down leg quantized it.
        for &v in avg_x.iter().chain(avg_acc.iter()) {
            assert_eq!(v.to_bits(), crate::util::half::round_f32(v).to_bits());
        }
        // The delta bases advanced, same contract as the lossy codecs.
        assert_eq!(c.base_x, avg_x);
        assert_eq!(c.base_acc, avg_acc);
    }

    #[test]
    fn compressed_sync_round_keeps_replica_state_sane() {
        let (n, d) = (2usize, 64usize);
        let net = NetModel::from_config(&crate::config::NetConfig::default());
        let mut c = CompressedCollective::qsgd(ChannelCollective::new(n, d), net, 15, 3);
        let xs: Vec<Vec<f32>> =
            (0..n).map(|w| (0..d).map(|i| (i as f32 + w as f32) * 0.01).collect()).collect();
        let accs: Vec<Vec<f32>> = (0..n).map(|_| vec![1.5f32; d]).collect();
        let mut avg_x = vec![0.0f32; d];
        let mut avg_acc = vec![0.0f32; d];
        let rep = c
            .sync_round(&refs(&xs), Some(&refs(&accs)), &mut avg_x, Some(&mut avg_acc))
            .unwrap();
        assert!(rep.bytes > 0);
        assert!(avg_x.iter().all(|v| v.is_finite()));
        // Denominators never go negative, even through the lossy roundtrip.
        assert!(avg_acc.iter().all(|&v| v >= 0.0));
        // The base advanced to the newly installed state.
        assert_eq!(c.base_x, avg_x);
        assert_eq!(c.base_acc, avg_acc);
    }

    #[test]
    fn topk_full_keep_sync_round_is_exact() {
        // keep = 1.0 transmits everything: delta compression is lossless,
        // so the round must agree with the plain channel mean exactly.
        let (n, d) = (3usize, 32usize);
        let net = NetModel::from_config(&crate::config::NetConfig::default());
        let mut c = CompressedCollective::topk(ChannelCollective::new(n, d), net, 1.0);
        let xs: Vec<Vec<f32>> =
            (0..n).map(|w| (0..d).map(|i| (i * (w + 1)) as f32 * 0.1).collect()).collect();
        let mut avg_x = vec![0.0f32; d];
        c.sync_round(&refs(&xs), None, &mut avg_x, None).unwrap();
        let mut want = vec![0.0f32; d];
        math::mean_into(&refs(&xs), &mut want);
        for i in 0..d {
            assert!((avg_x[i] - want[i]).abs() < 1e-5, "i={i}");
        }
    }

    #[test]
    fn standalone_allreduce_does_not_touch_sync_bases() {
        let (n, d) = (2usize, 16usize);
        let net = NetModel::from_config(&crate::config::NetConfig::default());
        let mut c = CompressedCollective::qsgd(ChannelCollective::new(n, d), net, 15, 3);
        // Establish a sync base.
        let xs: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0f32; d]).collect();
        let mut avg = vec![0.0f32; d];
        c.sync_round(&refs(&xs), None, &mut avg, None).unwrap();
        let base_before = c.base_x.clone();
        // A standalone allreduce of unrelated data must not move the base
        // or consume the sync streams.
        let other: Vec<Vec<f32>> = (0..n).map(|_| vec![5.0f32; d]).collect();
        let mut out = vec![0.0f32; d];
        c.allreduce_mean(&refs(&other), &mut out).unwrap();
        assert_eq!(c.base_x, base_before);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn single_worker_compression_is_identity() {
        let net = NetModel::from_config(&crate::config::NetConfig::default());
        let mut c = CompressedCollective::qsgd(ChannelCollective::new(1, 8), net, 4, 1);
        let mut grads = vec![vec![1.0f32; 8]];
        let rep = c.gather_grads(&mut grads).unwrap();
        assert_eq!(rep.bytes, 0);
        assert_eq!(grads[0], vec![1.0f32; 8]);
    }

    #[test]
    fn default_sync_round_partial_is_the_full_barrier() {
        let mut c = ChannelCollective::new(3, 2);
        let xs = vec![vec![0.0f32, 3.0], vec![3.0, 0.0], vec![3.0, 3.0]];
        let mut avg = vec![0.0f32; 2];
        let out = c
            .sync_round_partial(&refs(&xs), None, &[0.5, 0.25, 2.0], &mut avg, None)
            .unwrap();
        assert_eq!(out.participants, vec![0, 1, 2]);
        assert!(out.dropped.is_empty());
        assert_eq!(out.close_s, 2.0);
        assert_eq!(avg, vec![2.0, 2.0]);
        // Ragged arrivals are a protocol error.
        assert!(c.sync_round_partial(&refs(&xs), None, &[0.1], &mut avg, None).is_err());
    }

    #[test]
    fn participation_quorum_selection_and_close_time() {
        let p = Participation { quorum: 2, timeout_s: 0.0, drop_slowest: 0 };
        // Worker 2 is 4× slow: quorum of 2 closes without it.
        let (parts, dropped, close) = p.select(&[1.0, 1.0, 4.0]).unwrap();
        assert_eq!(parts, vec![0, 1]);
        assert_eq!(dropped, vec![2]);
        assert_eq!(close, 1.0); // t_q + timeout (someone was dropped)
        // A timeout large enough lets the straggler participate; the round
        // then closes at its (max) arrival, not at the full timeout.
        let p = Participation { quorum: 2, timeout_s: 5.0, drop_slowest: 0 };
        let (parts, dropped, close) = p.select(&[1.0, 1.0, 4.0]).unwrap();
        assert_eq!(parts, vec![0, 1, 2]);
        assert!(dropped.is_empty());
        assert_eq!(close, 4.0);
        // Equal arrivals: ties are inclusive — nobody is dropped.
        let p = Participation { quorum: 1, timeout_s: 0.0, drop_slowest: 0 };
        let (parts, dropped, close) = p.select(&[1.5, 1.5, 1.5]).unwrap();
        assert_eq!(parts, vec![0, 1, 2]);
        assert!(dropped.is_empty());
        assert_eq!(close, 1.5);
        // quorum = 0 is the documented full barrier: everyone participates
        // and the round closes at the slowest arrival.
        let p = Participation { quorum: 0, timeout_s: 0.0, drop_slowest: 0 };
        let (parts, dropped, close) = p.select(&[2.0, 1.0, 3.0]).unwrap();
        assert_eq!(parts, vec![0, 1, 2]);
        assert!(dropped.is_empty());
        assert_eq!(close, 3.0);
        // Quorum unreachable ⇒ a clean protocol error.
        let p = Participation { quorum: 4, timeout_s: 0.0, drop_slowest: 0 };
        let err = p.select(&[1.0, 1.0]).unwrap_err();
        assert!(err.to_string().contains("unreachable"), "{err}");
    }

    #[test]
    fn participation_backup_worker_drops_the_slowest_k() {
        let p = Participation { quorum: 0, timeout_s: 0.0, drop_slowest: 1 };
        let (parts, dropped, close) = p.select(&[2.0, 1.0, 3.0, 1.5]).unwrap();
        assert_eq!(parts, vec![0, 1, 3]);
        assert_eq!(dropped, vec![2]);
        assert_eq!(close, 2.0);
        // Equal arrivals: deterministic tie-break by index (highest dropped).
        let (parts, dropped, _) = p.select(&[1.0, 1.0, 1.0]).unwrap();
        assert_eq!(parts, vec![0, 1]);
        assert_eq!(dropped, vec![2]);
        // Never drops everyone.
        let p = Participation { quorum: 0, timeout_s: 0.0, drop_slowest: 9 };
        let (parts, dropped, _) = p.select(&[5.0, 1.0]).unwrap();
        assert_eq!(parts, vec![1]);
        assert_eq!(dropped, vec![0]);
    }

    #[test]
    fn partial_collective_averages_exactly_the_survivors() {
        // The quorum average must conserve the survivors' mean exactly —
        // bitwise the same arithmetic as a full round over just them.
        let (n, d) = (4usize, 16usize);
        let policy = Participation { quorum: 3, timeout_s: 0.0, drop_slowest: 0 };
        let mut pc =
            PartialCollective::new(Box::new(ChannelCollective::new(n, d)), policy);
        assert_eq!(pc.n(), n);
        assert!(pc.label().starts_with("partial(q3"));
        let xs: Vec<Vec<f32>> =
            (0..n).map(|w| (0..d).map(|i| (w * d + i) as f32 * 0.1).collect()).collect();
        let accs: Vec<Vec<f32>> = (0..n).map(|w| vec![1.0 + w as f32; d]).collect();
        let arrivals = [1.0, 1.0, 1.0, 9.0]; // worker 3 straggles
        let mut avg_x = vec![0.0f32; d];
        let mut avg_acc = vec![0.0f32; d];
        let out = pc
            .sync_round_partial(
                &refs(&xs),
                Some(&refs(&accs)),
                &arrivals,
                &mut avg_x,
                Some(&mut avg_acc),
            )
            .unwrap();
        assert_eq!(out.participants, vec![0, 1, 2]);
        assert_eq!(out.dropped, vec![3]);
        assert_eq!(out.close_s, 1.0);
        let survivors = refs(&xs[..3]);
        let mut want = vec![0.0f32; d];
        math::mean_into(&survivors, &mut want);
        assert_eq!(avg_x, want, "survivor mean not conserved bitwise");
        let acc_survivors = refs(&accs[..3]);
        math::mean_into(&acc_survivors, &mut want);
        assert_eq!(avg_acc, want);
    }

    #[test]
    fn build_collective_wraps_partial_from_faults_config() {
        let calib = Calibration::paper_v100();
        let mut cfg = ExperimentConfig::default();
        cfg.train.fused = false;
        cfg.faults.quorum = 7;
        let c = build_collective(&cfg, &calib, 16).unwrap();
        assert!(c.label().starts_with("partial(q7"), "{}", c.label());
        cfg.faults.quorum = 0;
        cfg.faults.drop_slowest = 1;
        let c = build_collective(&cfg, &calib, 16).unwrap();
        assert_eq!(c.label(), "partial(drop1, simulated(ps))");
    }

    #[test]
    fn build_collective_dispatches_on_config() {
        let calib = Calibration::paper_v100();
        let mut cfg = ExperimentConfig::default();
        assert_eq!(build_collective(&cfg, &calib, 16).unwrap().label(), "simulated(ps)");
        cfg.net.topology = "allreduce".into();
        assert_eq!(
            build_collective(&cfg, &calib, 16).unwrap().label(),
            "simulated(allreduce)"
        );
        cfg.comm.transport = "channel".into();
        assert_eq!(build_collective(&cfg, &calib, 16).unwrap().label(), "channel");
        cfg.comm.compression = "qsgd".into();
        cfg.comm.qsgd_levels = 15;
        assert_eq!(build_collective(&cfg, &calib, 16).unwrap().label(), "qsgd(s=15)");
        cfg.comm.compression = "topk".into();
        cfg.comm.topk_keep = 0.01;
        assert_eq!(build_collective(&cfg, &calib, 16).unwrap().label(), "topk(0.01)");
        cfg.comm.compression = "zstd".into();
        assert!(build_collective(&cfg, &calib, 16).is_err());
    }

    #[test]
    fn sharded_channel_sync_is_bitwise_dense() {
        // The tentpole equivalence pin at the collective layer: `shards = k`
        // averages per range, and every installed bit matches `shards = 1`.
        // d deliberately not divisible by k (uneven tail ranges).
        let (n, d, k) = (3usize, 131usize, 4usize);
        let xs: Vec<Vec<f32>> =
            (0..n).map(|w| (0..d).map(|i| ((i * 7 + w) as f32 * 0.013).sin()).collect()).collect();
        let accs: Vec<Vec<f32>> =
            (0..n).map(|w| (0..d).map(|i| ((i + w * 3) as f32 * 0.029).cos().abs()).collect()).collect();
        let mut dense = ChannelCollective::new(n, d);
        let mut sharded = ChannelCollective::sharded(n, d, k);
        assert_eq!(dense.label(), "channel");
        assert_eq!(sharded.label(), "channel(shards=4)");
        let (mut dx, mut da) = (vec![0.0f32; d], vec![0.0f32; d]);
        let (mut sx, mut sa) = (vec![0.0f32; d], vec![0.0f32; d]);
        dense.sync_round(&refs(&xs), Some(&refs(&accs)), &mut dx, Some(&mut da)).unwrap();
        sharded.sync_round(&refs(&xs), Some(&refs(&accs)), &mut sx, Some(&mut sa)).unwrap();
        assert!(dx.iter().zip(&sx).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(da.iter().zip(&sa).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn sharded_simulated_books_dense_bytes_and_divided_incast() {
        let calib = Calibration::paper_v100();
        let (d, k) = (131usize, 4usize);
        let mut cfg = ExperimentConfig::default();
        let n = cfg.train.workers;
        let dense_cost = SimCost::from_config(&cfg, &calib);
        cfg.comm.shards = k;
        let cost = SimCost::from_config(&cfg, &calib);
        let net = cost.net.clone();
        assert_eq!(net.shards, k);
        let mut sim =
            SimulatedCollective::new(ChannelCollective::sharded(n, d, k), cost);
        assert_eq!(sim.label(), "simulated(ps, shards=4)");

        let xs: Vec<Vec<f32>> = (0..n).map(|_| vec![2.0f32; d]).collect();
        let mut avg = vec![0.0f32; d];
        let rep = sim.sync_round(&refs(&xs), None, &mut avg, None).unwrap();
        // Traffic is shard-invariant: the per-range bills sum to the exact
        // dense total (linearity, u64 — no rounding even with uneven
        // ranges).
        assert_eq!(rep.bytes, dense_cost.net.sync_traffic_bytes(n, 4 * d as u64, 1));
        // Time: the k shard servers split the incast; strictly faster than
        // the single-leader round, and exactly what the sharded model says.
        let want_t = (1.0 - calib.periodic_overlap) * net.sync_time(n, calib.vector_bytes(), 1);
        assert!((rep.time_s - want_t).abs() < 1e-12);
        let dense_t = (1.0 - calib.periodic_overlap)
            * dense_cost.net.sync_time(n, calib.vector_bytes(), 1);
        assert!(rep.time_s < dense_t, "{} !< {}", rep.time_s, dense_t);
    }

    #[test]
    fn sharded_bf16_bills_dense_bytes_and_matches_dense_bitwise() {
        // bf16 is elementwise, so per-shard roundtrips are bitwise the
        // dense roundtrip and the per-range byte bills sum exactly.
        let (n, d, k) = (4usize, 131usize, 4usize);
        let net = NetModel::from_config(&crate::config::NetConfig::default());
        let mut dense = CompressedCollective::bf16(ChannelCollective::new(n, d), net.clone());
        let mut sharded = CompressedCollective::bf16(
            ChannelCollective::sharded(n, d, k),
            net.with_shards(k),
        );
        assert_eq!(sharded.label(), "bf16(shards=4)");
        let xs: Vec<Vec<f32>> =
            (0..n).map(|w| (0..d).map(|i| ((i + w) as f32 * 0.1).sin()).collect()).collect();
        let accs: Vec<Vec<f32>> = (0..n).map(|_| vec![1.5f32; d]).collect();
        let (mut dx, mut da) = (vec![0.0f32; d], vec![0.0f32; d]);
        let (mut sx, mut sa) = (vec![0.0f32; d], vec![0.0f32; d]);
        let drep = dense
            .sync_round(&refs(&xs), Some(&refs(&accs)), &mut dx, Some(&mut da))
            .unwrap();
        let srep = sharded
            .sync_round(&refs(&xs), Some(&refs(&accs)), &mut sx, Some(&mut sa))
            .unwrap();
        assert_eq!(srep.bytes, drep.bytes, "per-shard byte bills must sum to dense");
        assert!(dx.iter().zip(&sx).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(da.iter().zip(&sa).all(|(a, b)| a.to_bits() == b.to_bits()));

        // Gradient gather too.
        let grads: Vec<Vec<f32>> =
            (0..n).map(|w| (0..d).map(|i| ((i * 3 + w) as f32 * 0.07).cos()).collect()).collect();
        let mut dg = grads.clone();
        let mut sg = grads.clone();
        let drep = dense.gather_grads(&mut dg).unwrap();
        let srep = sharded.gather_grads(&mut sg).unwrap();
        assert_eq!(srep.bytes, drep.bytes);
        for (a, b) in dg.iter().flatten().zip(sg.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn build_collective_dispatches_sharded_transports() {
        let calib = Calibration::paper_v100();
        let mut cfg = ExperimentConfig::default();
        cfg.comm.shards = 4;
        assert_eq!(
            build_collective(&cfg, &calib, 16).unwrap().label(),
            "simulated(ps, shards=4)"
        );
        cfg.comm.transport = "channel".into();
        assert_eq!(
            build_collective(&cfg, &calib, 16).unwrap().label(),
            "channel(shards=4)"
        );
        cfg.precision.wire = "bf16".into();
        assert_eq!(
            build_collective(&cfg, &calib, 16).unwrap().label(),
            "bf16(shards=4)"
        );
        // Sharding shards the parameter server — ring topology is rejected
        // by the builder's re-run of the cross-section rule.
        cfg.precision.wire = "f32".into();
        cfg.comm.transport = "simulated".into();
        cfg.net.topology = "allreduce".into();
        let err = build_collective(&cfg, &calib, 16).unwrap_err();
        assert!(err.to_string().contains("comm.shards"), "{err}");
        // And the lossy codecs don't compose with a range partition.
        cfg.net.topology = "ps".into();
        cfg.comm.transport = "channel".into();
        cfg.comm.compression = "qsgd".into();
        let err = build_collective(&cfg, &calib, 16).unwrap_err();
        assert!(err.to_string().contains("comm.shards"), "{err}");
    }

    #[test]
    fn build_collective_selects_bf16_wire_from_precision() {
        let calib = Calibration::paper_v100();
        let mut cfg = ExperimentConfig::default();
        cfg.comm.transport = "channel".into();
        cfg.precision.wire = "bf16".into();
        assert_eq!(build_collective(&cfg, &calib, 16).unwrap().label(), "bf16");
        // The builder re-runs the precision × comm cross-rule for
        // programmatically-built configs.
        cfg.comm.transport = "simulated".into();
        let err = build_collective(&cfg, &calib, 16).unwrap_err();
        assert!(err.to_string().contains("channel"), "{err}");
        cfg.comm.transport = "channel".into();
        cfg.comm.compression = "qsgd".into();
        let err = build_collective(&cfg, &calib, 16).unwrap_err();
        assert!(err.to_string().contains("compression"), "{err}");
    }

    #[test]
    fn codec_roundtrip_matches_wire_payload_codec_bitwise() {
        // The equivalence the networked transport rests on: in-process
        // `Codec::roundtrip` on any stream produces exactly the vector a
        // remote peer gets by decoding the wire bytes of
        // `wire::PayloadCodec` on that stream — including the per-(stream,
        // use) QSGD draws.
        use crate::comm::wire::PayloadCodec;
        let (s, seed, d) = (15u8, 77u64, 193usize);
        let mut codec = Codec::Qsgd {
            q: QsgdQuantizer::new(s),
            seed,
            uses: Vec::new(),
            enc: QsgdEncoded { norm: 0.0, levels: Vec::new(), s },
        };
        let mut wire_codec = PayloadCodec::qsgd(s, seed);
        for stream in [0usize, 3, 11, 3, 0] {
            let src: Vec<f32> =
                (0..d).map(|i| ((i * (stream + 2)) as f32 * 0.013).sin()).collect();
            let mut inproc = src.clone();
            let billed = codec.roundtrip(stream, &mut inproc);
            let mut bytes = Vec::new();
            wire_codec.encode_vec(stream, &src, &mut bytes);
            assert_eq!(bytes.len() as u64, billed, "billed bytes != wire bytes");
            let mut remote = vec![0.0f32; d];
            wire_codec.decode_vec(&bytes, &mut remote).unwrap();
            for i in 0..d {
                assert_eq!(
                    inproc[i].to_bits(),
                    remote[i].to_bits(),
                    "stream {stream} elem {i}"
                );
            }
        }
        // bf16: same identity, stateless.
        let mut codec = Codec::Bf16;
        let mut wire_codec = PayloadCodec::Bf16;
        let src: Vec<f32> = (0..d).map(|i| (i as f32 * 0.37).cos()).collect();
        let mut inproc = src.clone();
        let billed = codec.roundtrip(0, &mut inproc);
        let mut bytes = Vec::new();
        wire_codec.encode_vec(0, &src, &mut bytes);
        assert_eq!(bytes.len() as u64, billed);
        let mut remote = vec![0.0f32; d];
        wire_codec.decode_vec(&bytes, &mut remote).unwrap();
        assert!(inproc.iter().zip(&remote).all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}
