//! Communication substrate: cost model for the paper's parameter-server
//! setting and ring all-reduce, plus traffic accounting.

pub mod compress;
pub mod netmodel;

pub use compress::{QsgdQuantizer, SparseGrad, TopKSparsifier};
pub use netmodel::{NetModel, Topology};
