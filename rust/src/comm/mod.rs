//! Communication substrate (DESIGN.md §3–§4): the pluggable collective
//! layer the trainer runs its protocol through, the leader↔worker message
//! transport (in-process channels or real TCP/Unix sockets), the binary
//! wire format, the α–β cost model for the paper's parameter-server
//! setting and ring all-reduce, and the gradient-compression codecs.

pub mod collective;
pub mod compress;
pub mod net;
pub mod netmodel;
pub mod shard;
pub mod transport;
pub mod wire;

pub use collective::{
    build_collective, ChannelCollective, Collective, CommReport, CompressedCollective,
    Participation, PartialCollective, PartialRound, SimCost, SimulatedCollective,
};
pub use compress::{QsgdQuantizer, SparseGrad, TopKSparsifier};
pub use net::{run_worker, LeaderLink, NetCounters, TcpTransport};
pub use netmodel::{tree_depth, NetModel, Topology};
pub use shard::ShardPlan;
pub use transport::ChannelTransport;
pub use wire::{config_fingerprint, Frame, FrameKind, PayloadCodec};
