//! Leader ↔ worker message transport — the lockstep request/reply channel
//! layer extracted from the trainer (DESIGN.md §3).
//!
//! The coordinator's control plane is a strict request/reply protocol: the
//! leader broadcasts one command to every worker and then gathers exactly
//! one reply per worker (the synchronous-training barrier of the paper,
//! §2). This module owns that plumbing generically over the command/reply
//! types, so the trainer deals in protocol *intent* and the
//! [`super::collective`] layer deals in data-plane cost; neither touches
//! raw `mpsc` endpoints.
//!
//! Workers are addressed by id regardless of how they are *hosted*: each
//! worker either owns a dedicated channel ([`ChannelTransport::from_parts`])
//! or shares a host thread's channel with siblings, in which case the
//! transport tags each command with the worker id
//! ([`ChannelTransport::from_hosts`]; the execution engine of DESIGN.md §7
//! multiplexes several workers onto one host thread this way).

use std::sync::mpsc::{Receiver, Sender};
use std::thread::JoinHandle;

use crate::error::{Error, Result};

/// How commands reach one worker: a dedicated channel, or a host thread's
/// shared channel (commands tagged with the worker id).
enum Route<C> {
    Direct(Sender<C>),
    Shared(Sender<(usize, C)>),
}

impl<C> Route<C> {
    fn send(&self, w: usize, cmd: C) -> std::result::Result<(), ()> {
        match self {
            Route::Direct(tx) => tx.send(cmd).map_err(|_| ()),
            Route::Shared(tx) => tx.send((w, cmd)).map_err(|_| ()),
        }
    }
}

/// A lockstep request/reply transport over in-process channels: one command
/// route per worker, one shared reply receiver.
pub struct ChannelTransport<C, R> {
    routes: Vec<Route<C>>,
    rx: Receiver<R>,
    joins: Vec<JoinHandle<()>>,
}

impl<C, R> ChannelTransport<C, R> {
    /// Assemble from already-spawned worker endpoints. `txs[i]` feeds
    /// worker `i`; every worker shares the sender side of `rx`.
    pub fn from_parts(txs: Vec<Sender<C>>, rx: Receiver<R>, joins: Vec<JoinHandle<()>>) -> Self {
        ChannelTransport {
            routes: txs.into_iter().map(Route::Direct).collect(),
            rx,
            joins,
        }
    }

    /// Assemble from host-thread endpoints: `host_txs[i]` feeds worker `i`
    /// and may be a clone of a sibling's sender when several workers share
    /// one host thread; commands arrive on the host channel tagged
    /// `(worker, cmd)`. `joins` holds one handle per host thread.
    pub fn from_hosts(
        host_txs: Vec<Sender<(usize, C)>>,
        rx: Receiver<R>,
        joins: Vec<JoinHandle<()>>,
    ) -> Self {
        ChannelTransport {
            routes: host_txs.into_iter().map(Route::Shared).collect(),
            rx,
            joins,
        }
    }

    /// Number of workers.
    pub fn n(&self) -> usize {
        self.routes.len()
    }

    /// Send `make(w)` to every worker `w` (the control-plane broadcast).
    pub fn broadcast(&self, mut make: impl FnMut(usize) -> C) -> Result<()> {
        for (w, route) in self.routes.iter().enumerate() {
            route
                .send(w, make(w))
                .map_err(|_| Error::Protocol(format!("worker {w} channel closed")))?;
        }
        Ok(())
    }

    /// Send `make(w)` to each worker in `targets` — the fault-aware subset
    /// broadcast (crashed workers are simply never addressed; DESIGN.md §6).
    pub fn broadcast_to(&self, targets: &[usize], mut make: impl FnMut(usize) -> C) -> Result<()> {
        for &w in targets {
            self.send_to(w, make(w))?;
        }
        Ok(())
    }

    /// Send one command to a single worker.
    pub fn send_to(&self, w: usize, cmd: C) -> Result<()> {
        self.routes
            .get(w)
            .ok_or_else(|| Error::Protocol(format!("no worker {w}")))?
            .send(w, cmd)
            .map_err(|_| Error::Protocol(format!("worker {w} channel closed")))
    }

    /// Receive the next reply from any worker.
    pub fn recv(&self) -> Result<R> {
        self.rx
            .recv()
            .map_err(|_| Error::Protocol("all workers disconnected".into()))
    }

    /// Gather exactly one reply per worker, delivering each to `each` in
    /// **arrival order** as it lands — the streaming form the pipelined
    /// sync path builds on (`[comm] pipeline`): the leader can stage or
    /// reduce worker `w`'s payload while the remaining workers are still
    /// replying, instead of barriering on the full set first. Duplicate
    /// or unknown-worker replies are protocol violations.
    pub fn gather_each<T>(
        &self,
        mut sel: impl FnMut(R) -> Result<(usize, T)>,
        mut each: impl FnMut(usize, T) -> Result<()>,
    ) -> Result<()> {
        let n = self.n();
        let mut seen = vec![false; n];
        let mut got = 0;
        while got < n {
            let (w, v) = sel(self.recv()?)?;
            let slot = seen
                .get_mut(w)
                .ok_or_else(|| Error::Protocol(format!("reply from unknown worker {w}")))?;
            if std::mem::replace(slot, true) {
                return Err(Error::Protocol(format!("duplicate reply from worker {w}")));
            }
            each(w, v)?;
            got += 1;
        }
        Ok(())
    }

    /// Gather exactly one reply per worker; `sel` extracts the worker index
    /// and payload (and turns error replies into `Err`). Duplicate or
    /// missing replies are protocol violations. (The barrier form of
    /// [`ChannelTransport::gather_each`]: results returned in worker
    /// order once all have arrived.)
    pub fn gather<T>(&self, sel: impl FnMut(R) -> Result<(usize, T)>) -> Result<Vec<T>> {
        let n = self.n();
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        self.gather_each(sel, |w, v| {
            out[w] = Some(v);
            Ok(())
        })?;
        Ok(out.into_iter().map(|v| v.unwrap()).collect())
    }

    /// Gather exactly one reply from each worker in `targets`, returned in
    /// target order. Replies from workers outside the set, duplicates, and
    /// unknown worker ids are protocol violations — the subset analogue of
    /// [`ChannelTransport::gather`] for partial-participation rounds.
    pub fn gather_from<T>(
        &self,
        targets: &[usize],
        mut sel: impl FnMut(R) -> Result<(usize, T)>,
    ) -> Result<Vec<T>> {
        let mut slot_of: Vec<Option<usize>> = vec![None; self.n()];
        for (i, &w) in targets.iter().enumerate() {
            let slot = slot_of
                .get_mut(w)
                .ok_or_else(|| Error::Protocol(format!("no worker {w}")))?;
            if slot.replace(i).is_some() {
                return Err(Error::Protocol(format!("duplicate gather target {w}")));
            }
        }
        let mut out: Vec<Option<T>> = (0..targets.len()).map(|_| None).collect();
        let mut got = 0;
        while got < targets.len() {
            let (w, v) = sel(self.recv()?)?;
            let slot = slot_of.get(w).copied().flatten().ok_or_else(|| {
                Error::Protocol(format!("unexpected reply from worker {w}"))
            })?;
            if out[slot].replace(v).is_some() {
                return Err(Error::Protocol(format!("duplicate reply from worker {w}")));
            }
            got += 1;
        }
        Ok(out.into_iter().map(|v| v.unwrap()).collect())
    }

    /// Best-effort shutdown: send `stop(w)` to every worker and join the
    /// threads. Errors are swallowed — shutdown runs on all exit paths,
    /// including after a protocol error already tore channels down.
    pub fn shutdown(&mut self, mut stop: impl FnMut(usize) -> C) {
        for (w, route) in self.routes.iter().enumerate() {
            let _ = route.send(w, stop(w));
        }
        self.routes.clear();
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    /// Spin up `n` echo workers that double incoming integers.
    fn echo_transport(n: usize) -> ChannelTransport<Option<u64>, (usize, u64)> {
        let (reply_tx, reply_rx) = channel();
        let mut txs = Vec::new();
        let mut joins = Vec::new();
        for w in 0..n {
            let (tx, rx) = channel::<Option<u64>>();
            let rtx = reply_tx.clone();
            joins.push(std::thread::spawn(move || {
                while let Ok(Some(v)) = rx.recv() {
                    if rtx.send((w, v * 2)).is_err() {
                        break;
                    }
                }
            }));
            txs.push(tx);
        }
        ChannelTransport::from_parts(txs, reply_rx, joins)
    }

    #[test]
    fn broadcast_gather_roundtrip() {
        let mut t = echo_transport(4);
        t.broadcast(|w| Some(w as u64 + 1)).unwrap();
        let replies = t.gather(|(w, v)| Ok((w, v))).unwrap();
        assert_eq!(replies, vec![2, 4, 6, 8]);
        t.shutdown(|_| None);
    }

    #[test]
    fn send_to_targets_one_worker() {
        let mut t = echo_transport(3);
        t.send_to(1, Some(21)).unwrap();
        let (w, v) = t.recv().unwrap();
        assert_eq!((w, v), (1, 42));
        assert!(t.send_to(7, Some(0)).is_err());
        t.shutdown(|_| None);
    }

    #[test]
    fn gather_each_streams_in_arrival_order() {
        // Replies queued 2, 0, 1 — the streaming gather must deliver them
        // in exactly that arrival order, not worker order.
        let (tx0, _rx0) = channel::<Option<u64>>();
        let (tx1, _rx1) = channel::<Option<u64>>();
        let (tx2, _rx2) = channel::<Option<u64>>();
        let (reply_tx, reply_rx) = channel();
        for w in [2usize, 0, 1] {
            reply_tx.send((w, w as u64 * 10)).unwrap();
        }
        let t = ChannelTransport::from_parts(vec![tx0, tx1, tx2], reply_rx, Vec::new());
        let mut order = Vec::new();
        t.gather_each(
            |(w, v)| Ok((w, v)),
            |w, v| {
                order.push((w, v));
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(order, vec![(2, 20), (0, 0), (1, 10)]);
    }

    #[test]
    fn gather_rejects_duplicates() {
        // A 2-worker transport whose reply queue carries two replies from
        // worker 0 (the command senders are never used).
        let (tx0, _rx0) = channel::<Option<u64>>();
        let (tx1, _rx1) = channel::<Option<u64>>();
        let (reply_tx, reply_rx) = channel();
        reply_tx.send((0usize, 1u64)).unwrap();
        reply_tx.send((0usize, 2u64)).unwrap();
        let t = ChannelTransport::from_parts(vec![tx0, tx1], reply_rx, Vec::new());
        let err = t.gather(|(w, v)| Ok((w, v))).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn subset_broadcast_and_gather_skip_unaddressed_workers() {
        let mut t = echo_transport(4);
        // Address only workers 0 and 2; 1 and 3 never see a command and
        // therefore never reply — the gather must not wait on them.
        t.broadcast_to(&[0, 2], |w| Some(w as u64 + 10)).unwrap();
        let replies = t.gather_from(&[0, 2], |(w, v)| Ok((w, v))).unwrap();
        assert_eq!(replies, vec![20, 24]);
        // Unknown target ids are rejected up front.
        assert!(t.broadcast_to(&[7], |_| Some(0)).is_err());
        assert!(t.gather_from(&[7], |(w, v): (usize, u64)| Ok((w, v))).is_err());
        t.shutdown(|_| None);
    }

    #[test]
    fn gather_from_rejects_replies_outside_the_target_set() {
        // Reply queue carries worker 1's answer while only worker 0 is
        // targeted — a protocol violation, not a hang.
        let (tx0, _rx0) = channel::<Option<u64>>();
        let (tx1, _rx1) = channel::<Option<u64>>();
        let (reply_tx, reply_rx) = channel();
        reply_tx.send((1usize, 5u64)).unwrap();
        let t = ChannelTransport::from_parts(vec![tx0, tx1], reply_rx, Vec::new());
        let err = t.gather_from(&[0], |(w, v)| Ok((w, v))).unwrap_err();
        assert!(err.to_string().contains("unexpected"), "{err}");
    }

    #[test]
    fn shared_host_routes_tag_the_worker() {
        // Two host threads each multiplex two echo workers over one shared
        // channel; commands arrive tagged (worker, value) and replies keep
        // the worker id, so the gather slots them correctly.
        let (n, hosts) = (4usize, 2usize);
        let (reply_tx, reply_rx) = channel();
        let mut unique_txs = Vec::new();
        let mut joins = Vec::new();
        for _ in 0..hosts {
            let (tx, rx) = channel::<(usize, Option<u64>)>();
            let rtx = reply_tx.clone();
            let per_host = n / hosts;
            joins.push(std::thread::spawn(move || {
                let mut stops = 0;
                while let Ok((w, cmd)) = rx.recv() {
                    match cmd {
                        Some(v) => {
                            if rtx.send((w, v * 2)).is_err() {
                                break;
                            }
                        }
                        None => {
                            stops += 1;
                            if stops == per_host {
                                break;
                            }
                        }
                    }
                }
            }));
            unique_txs.push(tx);
        }
        drop(reply_tx);
        let host_txs: Vec<_> = (0..n).map(|w| unique_txs[w % hosts].clone()).collect();
        drop(unique_txs);
        let mut t = ChannelTransport::from_hosts(host_txs, reply_rx, joins);
        assert_eq!(t.n(), n);
        t.broadcast(|w| Some(w as u64 + 1)).unwrap();
        let replies = t.gather(|(w, v)| Ok((w, v))).unwrap();
        assert_eq!(replies, vec![2, 4, 6, 8]);
        // Subset addressing still works through shared routes.
        t.broadcast_to(&[1, 3], |w| Some(w as u64)).unwrap();
        let replies = t.gather_from(&[1, 3], |(w, v)| Ok((w, v))).unwrap();
        assert_eq!(replies, vec![2, 6]);
        t.shutdown(|_| None);
    }

    #[test]
    fn recv_after_workers_gone_errors() {
        let (reply_tx, reply_rx) = channel::<u64>();
        drop(reply_tx);
        let t = ChannelTransport::<Option<u64>, u64>::from_parts(Vec::new(), reply_rx, Vec::new());
        assert!(t.recv().is_err());
    }
}
