//! `bench_diff`: the bench-ratchet checker for the machine-readable
//! `BENCH_*.json` documents written by [`adaalter::util::timing::BenchSink`].
//!
//! Compares a committed baseline against a fresh run, row by `name`:
//!
//! * **Timings** (`median_ns`): FAIL when the current run is more than
//!   `threshold`× slower than the baseline (default 1.15 — the CI
//!   bench-smoke ratchet). Faster is always fine: baselines are
//!   deliberately conservative.
//! * **Byte counts** (any metric key containing `bytes`): FAIL unless
//!   exactly equal — wire accounting is deterministic, so a single byte
//!   of drift is a bug, not noise.
//! * **Rates** (`per_s` / `speedup` metrics): WARN when the current run
//!   falls below baseline ÷ threshold. Parallel/SIMD gains depend on the
//!   host, so these never fail CI on shared runners.
//! * Rows present only in the baseline WARN (a renamed or deleted bench
//!   row silently drops ratchet coverage); rows only in the current run
//!   are noted.
//!
//! Usage: `bench_diff <baseline.json> <current.json> [threshold]`
//! Exits non-zero iff any FAIL was recorded.

use std::collections::BTreeMap;
use std::process::ExitCode;

use adaalter::util::json::Json;

const DEFAULT_THRESHOLD: f64 = 1.15;

/// Everything one comparison produced, separated by severity.
#[derive(Debug, Default)]
struct Report {
    failures: Vec<String>,
    warnings: Vec<String>,
    notes: Vec<String>,
}

/// Index a `BenchSink` document's rows by their `name` field.
fn rows_by_name(doc: &Json) -> Result<BTreeMap<&str, &Json>, String> {
    let rows = doc
        .get("rows")
        .ok_or("document has no \"rows\" field")?
        .arr()
        .map_err(|e| e.to_string())?;
    let mut out = BTreeMap::new();
    for row in rows {
        let name = row
            .get("name")
            .ok_or("row has no \"name\" field")?
            .str()
            .map_err(|e| e.to_string())?;
        out.insert(name, row);
    }
    Ok(out)
}

fn num_field(row: &Json, key: &str) -> Option<f64> {
    match row.get(key) {
        Some(Json::Num(n)) => Some(*n),
        _ => None,
    }
}

/// Compare one (baseline, current) row pair into `rep`.
fn diff_row(name: &str, base: &Json, cur: &Json, threshold: f64, rep: &mut Report) {
    if let (Some(b), Some(c)) = (num_field(base, "median_ns"), num_field(cur, "median_ns")) {
        let ratio = c / b;
        if c > b * threshold {
            rep.failures.push(format!(
                "{name}: median {c:.0} ns vs baseline {b:.0} ns ({ratio:.2}x > {threshold}x)"
            ));
        } else {
            rep.notes.push(format!("{name}: median {c:.0} ns ({ratio:.2}x of baseline)"));
        }
    }
    let empty = BTreeMap::new();
    let base_metrics = base.get("metrics").and_then(|m| m.obj().ok()).unwrap_or(&empty);
    let cur_metrics = cur.get("metrics").and_then(|m| m.obj().ok()).unwrap_or(&empty);
    for (key, bval) in base_metrics {
        let b = match bval {
            Json::Num(n) => *n,
            _ => continue,
        };
        let c = match cur_metrics.get(key) {
            Some(Json::Num(n)) => *n,
            _ => {
                rep.warnings.push(format!("{name}: metric {key} missing from current run"));
                continue;
            }
        };
        if key.contains("bytes") {
            // Wire/byte accounting is exact by construction; compare bits.
            if c.to_bits() != b.to_bits() {
                rep.failures.push(format!("{name}: {key} = {c} vs baseline {b} (must be exact)"));
            }
        } else if (key.contains("per_s") || key.contains("speedup")) && c < b / threshold {
            rep.warnings
                .push(format!("{name}: {key} = {c:.3} below baseline {b:.3} / {threshold}"));
        }
    }
}

/// Compare two parsed `BENCH_*.json` documents.
fn diff(baseline: &Json, current: &Json, threshold: f64) -> Result<Report, String> {
    let base_rows = rows_by_name(baseline)?;
    let cur_rows = rows_by_name(current)?;
    let mut rep = Report::default();
    for (name, base) in &base_rows {
        match cur_rows.get(name) {
            Some(cur) => diff_row(name, base, cur, threshold, &mut rep),
            None => rep
                .warnings
                .push(format!("{name}: row in baseline but not in current run")),
        }
    }
    for name in cur_rows.keys() {
        if !base_rows.contains_key(name) {
            rep.notes.push(format!("{name}: new row (no baseline yet)"));
        }
    }
    Ok(rep)
}

fn load(path: &str) -> Result<Json, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("cannot parse {path}: {e}"))
}

fn run(args: &[String]) -> Result<Report, String> {
    let (baseline, current) = match args {
        [b, c] | [b, c, _] => (load(b)?, load(c)?),
        _ => return Err("usage: bench_diff <baseline.json> <current.json> [threshold]".into()),
    };
    let threshold = match args.get(2) {
        Some(t) => t.parse::<f64>().map_err(|e| format!("bad threshold {t:?}: {e}"))?,
        None => DEFAULT_THRESHOLD,
    };
    diff(&baseline, &current, threshold)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(rep) => {
            for n in &rep.notes {
                println!("  ok  {n}");
            }
            for w in &rep.warnings {
                println!("WARN  {w}");
            }
            for f in &rep.failures {
                println!("FAIL  {f}");
            }
            println!(
                "\nbench_diff: {} failures, {} warnings, {} rows ok",
                rep.failures.len(),
                rep.warnings.len(),
                rep.notes.len()
            );
            if rep.failures.is_empty() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("bench_diff: {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(rows: &str) -> Json {
        Json::parse(&format!("{{\"bench\":\"t\",\"rows\":[{rows}]}}")).unwrap()
    }

    #[test]
    fn within_threshold_passes() {
        let b = doc(r#"{"name":"k","median_ns":100.0,"metrics":{}}"#);
        let c = doc(r#"{"name":"k","median_ns":110.0,"metrics":{}}"#);
        let rep = diff(&b, &c, 1.15).unwrap();
        assert!(rep.failures.is_empty(), "{rep:?}");
        assert_eq!(rep.notes.len(), 1);
    }

    #[test]
    fn slow_regression_fails() {
        let b = doc(r#"{"name":"k","median_ns":100.0,"metrics":{}}"#);
        let c = doc(r#"{"name":"k","median_ns":120.0,"metrics":{}}"#);
        let rep = diff(&b, &c, 1.15).unwrap();
        assert_eq!(rep.failures.len(), 1);
        assert!(rep.failures[0].contains("median"), "{rep:?}");
    }

    #[test]
    fn much_faster_is_fine() {
        let b = doc(r#"{"name":"k","median_ns":1000.0,"metrics":{}}"#);
        let c = doc(r#"{"name":"k","median_ns":10.0,"metrics":{}}"#);
        assert!(diff(&b, &c, 1.15).unwrap().failures.is_empty());
    }

    #[test]
    fn byte_metrics_must_match_exactly() {
        let b = doc(r#"{"name":"k","metrics":{"wire_bytes":2048}}"#);
        let ok = doc(r#"{"name":"k","metrics":{"wire_bytes":2048}}"#);
        let off = doc(r#"{"name":"k","metrics":{"wire_bytes":2049}}"#);
        assert!(diff(&b, &ok, 1.15).unwrap().failures.is_empty());
        let rep = diff(&b, &off, 1.15).unwrap();
        assert_eq!(rep.failures.len(), 1);
        assert!(rep.failures[0].contains("wire_bytes"));
    }

    #[test]
    fn rate_drops_warn_but_do_not_fail() {
        let b = doc(r#"{"name":"s","metrics":{"simd_speedup":2.0}}"#);
        let c = doc(r#"{"name":"s","metrics":{"simd_speedup":1.2}}"#);
        let rep = diff(&b, &c, 1.15).unwrap();
        assert!(rep.failures.is_empty());
        assert_eq!(rep.warnings.len(), 1);
    }

    #[test]
    fn missing_rows_warn_new_rows_note() {
        let b = doc(r#"{"name":"gone","median_ns":1.0,"metrics":{}}"#);
        let c = doc(r#"{"name":"fresh","median_ns":1.0,"metrics":{}}"#);
        let rep = diff(&b, &c, 1.15).unwrap();
        assert!(rep.failures.is_empty());
        assert_eq!(rep.warnings.len(), 1);
        assert!(rep.warnings[0].contains("gone"));
        assert!(rep.notes.iter().any(|n| n.contains("fresh")));
    }

    #[test]
    fn malformed_documents_are_rejected() {
        let good = doc(r#"{"name":"k","metrics":{}}"#);
        let no_rows = Json::parse(r#"{"bench":"t"}"#).unwrap();
        assert!(diff(&no_rows, &good, 1.15).is_err());
        let unnamed = Json::parse(r#"{"rows":[{"median_ns":1}]}"#).unwrap();
        assert!(diff(&unnamed, &good, 1.15).is_err());
    }
}
