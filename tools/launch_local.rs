//! `launch_local`: spawn a complete networked deployment on this machine —
//! one `adaalter` leader process plus one worker process per configured
//! worker, wired over loopback TCP (or a Unix socket) with port-0
//! port-file discovery (DESIGN.md §4).
//!
//! ```text
//! launch_local --experiment tcp-loopback [--set k=v]... [--out-dir d]
//! launch_local --config file.toml [--uds]
//! ```
//!
//! The tool resolves the config exactly like `adaalter train` (preset or
//! file, then `--set` overrides) to learn the worker count, then execs the
//! sibling `adaalter` binary for every role. Worker stdout/stderr are
//! inherited; the leader's exit code is the tool's exit code, and every
//! child is killed if any other child fails first.

use std::path::PathBuf;
use std::process::{Child, Command, ExitCode};

use adaalter::cli::Args;
use adaalter::config::{self, ExperimentConfig, TomlDoc};
use adaalter::error::{Error, Result};

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("launch_local: {e}");
            ExitCode::FAILURE
        }
    }
}

/// A child process killed on drop, so one failed role never leaves the
/// rest of the deployment running.
struct Guard {
    label: String,
    child: Child,
}

impl Drop for Guard {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// The `adaalter` binary next to this one.
fn adaalter_bin() -> Result<PathBuf> {
    let me = std::env::current_exe()?;
    let bin = me
        .parent()
        .ok_or_else(|| Error::Config("cannot locate the adaalter binary".into()))?
        .join("adaalter");
    if !bin.exists() {
        return Err(Error::Config(format!(
            "adaalter binary not found at {} (build the full workspace first)",
            bin.display()
        )));
    }
    Ok(bin)
}

fn run(argv: &[String]) -> Result<ExitCode> {
    let args = Args::parse(
        argv,
        &["experiment", "config", "set", "out-dir"],
        &["uds", "quiet", "help"],
    )?;
    if args.has("help") || args.command == "help" {
        println!(
            "launch_local — run an adaalter leader + worker fleet over loopback sockets
USAGE:
  launch_local --experiment <preset> [--set k=v]... [--out-dir d] [--uds] [--quiet]
  launch_local --config <file.toml>  [--set k=v]... [--out-dir d] [--uds] [--quiet]"
        );
        return Ok(ExitCode::SUCCESS);
    }

    // Resolve the config the same way `adaalter train` does, so the
    // worker count (and validation errors) match what the leader will see.
    let mut doc = if let Some(path) = args.get("config") {
        TomlDoc::load(path)?
    } else {
        config::preset_doc(args.get_or("experiment", "tcp-loopback"))?
    };
    let mut sets: Vec<String> = args.get_all("set").to_vec();
    if args.has("uds") {
        sets.push("comm.transport=uds".to_string());
    }
    for spec in &sets {
        ExperimentConfig::override_from_doc(&mut doc, spec)?;
    }
    let cfg = ExperimentConfig::from_doc(&doc)?;
    if !cfg.comm.networked() {
        return Err(Error::Config(format!(
            "launch_local needs comm.transport = \"tcp\" or \"uds\", got {:?} \
             (try --experiment tcp-loopback)",
            cfg.comm.transport
        )));
    }

    let out_dir = args.get_or("out-dir", &cfg.out_dir).to_string();
    std::fs::create_dir_all(&out_dir)?;
    let port_file = format!("{out_dir}/leader.addr");
    let _ = std::fs::remove_file(&port_file);
    let listen = if args.has("uds") {
        format!("{out_dir}/leader.sock")
    } else {
        "127.0.0.1:0".to_string()
    };
    let bin = adaalter_bin()?;

    let common_args = |cmd: &mut Command| {
        cmd.arg("train");
        if let Some(path) = args.get("config") {
            cmd.args(["--config", path]);
        } else {
            cmd.args(["--experiment", args.get_or("experiment", "tcp-loopback")]);
        }
        for spec in &sets {
            cmd.args(["--set", spec]);
        }
        if args.has("quiet") {
            cmd.arg("--quiet");
        }
    };

    let mut leader = Command::new(&bin);
    common_args(&mut leader);
    leader.args(["--role", "leader", "--listen", &listen]);
    leader.args(["--port-file", &port_file, "--out-dir", &out_dir]);
    let mut leader = Guard { label: "leader".into(), child: leader.spawn()? };

    let mut workers: Vec<Guard> = Vec::new();
    for w in 0..cfg.train.workers {
        let mut c = Command::new(&bin);
        common_args(&mut c);
        c.args(["--role", "worker", "--worker-id", &w.to_string()]);
        c.args(["--port-file", &port_file]);
        workers.push(Guard { label: format!("worker {w}"), child: c.spawn()? });
    }

    // The leader finishes last in a clean run (it sends Stop on the way
    // out); wait for the workers first so their failures surface before a
    // leader timeout does.
    let mut failed: Option<String> = None;
    for g in &mut workers {
        let status = g.child.wait()?;
        if !status.success() && failed.is_none() {
            failed = Some(format!("{} exited with {status}", g.label));
        }
    }
    let status = leader.child.wait()?;
    if !status.success() && failed.is_none() {
        failed = Some(format!("leader exited with {status}"));
    }
    match failed {
        Some(msg) => {
            eprintln!("launch_local: {msg}");
            Ok(ExitCode::FAILURE)
        }
        None => Ok(ExitCode::SUCCESS),
    }
}
